"""Content-addressed globals shipping: the blob store, the int8+EF payload
codec, zero-copy OOB frames, the put/need backfill protocol, and the warm
backend pool.

These are the acceptance tests for the payload pipeline: repeated dispatch
of a task over the same multi-MB global must stop re-sending the world
(bytes-on-wire drop ≥5x after the first send), mutation of a mutable global
between futures must invalidate the digest, eviction and self-healed
replacement workers must stay correct through the ``("need", digest)``
backfill, and ``plan()`` round-trips must re-attach to live workers.
"""

import os
import pickle
import socket
import time

import numpy as np
import pytest

import repro.core as rc
from repro.core import future, future_map, value
from repro.core import planning as plan_mod
from repro.core.backends import transport
from repro.core.backends.blobstore import (BlobStore, PayloadRef,
                                           PAYLOAD_REF_THRESHOLD,
                                           blob_digest, content_digest)


# --------------------------------------------------------------------------
# BlobStore unit behaviour
# --------------------------------------------------------------------------

def test_blobstore_lru_eviction_by_bytes():
    store = BlobStore(max_bytes=100)
    store.put(b"a" * 16, b"x" * 40)
    store.put(b"b" * 16, b"y" * 40)
    assert b"a" * 16 in store and b"b" * 16 in store
    store.get(b"a" * 16)                    # touch: a becomes most-recent
    store.put(b"c" * 16, b"z" * 40)         # over budget: evict LRU (b)
    assert b"b" * 16 not in store
    assert b"a" * 16 in store and b"c" * 16 in store
    assert store.stats()["evictions"] == 1


def test_blobstore_resolve_caches_decoded_arrays():
    store = BlobStore()
    arr = np.arange(6000, dtype=np.float32)
    digest = content_digest(arr)
    store.put(digest, transport.encode_payload(arr))
    v1 = store.resolve(digest)
    v2 = store.resolve(digest)
    assert v1 is v2                          # decoded-object cache hit
    assert not v1.flags.writeable            # handed out read-only
    np.testing.assert_allclose(v1, arr, atol=float(np.abs(arr).max()) / 127)


def test_blobstore_put_replacement_invalidates_decoded_cache():
    """A byte-different blob arriving for an already-decoded digest must
    drop the decoded-object cache entry, or resolve() would keep serving
    the value decoded from the old bytes."""
    store = BlobStore()
    digest = b"d" * 16
    a = np.arange(5000, dtype=np.int64)
    b = a * 2
    store.put(digest, transport.encode_payload(a))
    np.testing.assert_array_equal(store.resolve(digest), a)
    store.put(digest, transport.encode_payload(b))
    np.testing.assert_array_equal(store.resolve(digest), b)


def test_content_digest_is_memoized_and_content_addressed():
    a = np.random.default_rng(0).standard_normal(5000).astype(np.float32)
    assert content_digest(a) == content_digest(a)
    assert content_digest(a) == content_digest(a.copy())   # same content
    b = a.copy()
    b[0] += 1.0
    assert content_digest(a) != content_digest(b)          # new content


# --------------------------------------------------------------------------
# Payload codec: lossless raw by default, opt-in int8+EF with bounded error
# --------------------------------------------------------------------------

@pytest.fixture
def int8_codec():
    """Opt the lossy int8+EF codec in for one test (it is off by default:
    backend transparency means quantization must be explicit)."""
    transport.set_array_codec("int8")
    yield
    transport.set_array_codec("raw")


def test_float_arrays_ship_lossless_by_default():
    x = np.random.default_rng(4).standard_normal(8192).astype(np.float32)
    blob = transport.encode_payload(x)
    assert blob[0] == transport.P_RAWARR
    got, _ = transport.decode_payload(blob)
    np.testing.assert_array_equal(got, x)    # bit-exact, no quantization


def test_set_array_codec_toggles_and_validates():
    assert not transport.ARRAY_CODEC_INT8
    try:
        transport.set_array_codec("int8")
        assert transport.ARRAY_CODEC_INT8
        with pytest.raises(ValueError):
            transport.set_array_codec("zstd")
    finally:
        transport.set_array_codec("raw")
    assert not transport.ARRAY_CODEC_INT8


def test_codec_toggle_changes_float_array_digest():
    """A digest names the bytes that ship: toggling the codec must yield a
    new digest for float arrays (so no digest-keyed cache — driver store,
    worker stores, per-worker known sets — can replay a blob encoded under
    the other codec), while non-float arrays keep theirs."""
    x = np.random.default_rng(9).standard_normal(8192).astype(np.float32)
    ints = np.arange(8192, dtype=np.int64)
    d_raw, d_ints = content_digest(x), content_digest(ints)
    assert transport.encode_payload(x)[0] == transport.P_RAWARR
    try:
        transport.set_array_codec("int8")
        assert content_digest(x) != d_raw
        assert content_digest(ints) == d_ints     # int64 never quantized
        assert transport.encode_payload(x)[0] == transport.P_INT8
    finally:
        transport.set_array_codec("raw")
    assert content_digest(x) == d_raw


def test_int8_codec_compresses_float32_at_least_3_5x(int8_codec):
    x = np.random.default_rng(1).standard_normal(1 << 16).astype(np.float32)
    raw = len(pickle.dumps(x, pickle.HIGHEST_PROTOCOL))
    blob = transport.encode_payload(x)
    assert blob[0] == transport.P_INT8
    assert raw >= 3.5 * len(blob), (raw, len(blob))


def test_int8_codec_round_trip_error_is_bounded(int8_codec):
    """Conformance bound: per-tensor symmetric int8 with fp32 scale keeps
    |x - deq(q(x))| <= max|x|/127 elementwise (half a quantization step is
    the ideal; a full step is the safe contract)."""
    rng = np.random.default_rng(2)
    for scale_exp in (-3, 0, 4):
        x = (rng.standard_normal(1 << 14) * 10.0 ** scale_exp) \
            .astype(np.float32)
        got, cacheable = transport.decode_payload(transport.encode_payload(x))
        assert cacheable
        bound = float(np.abs(x).max()) / 127 + 1e-9
        assert float(np.abs(got - x).max()) <= bound


def test_error_feedback_reinjects_quantization_error(int8_codec):
    """Shipping an evolving tensor under one global name accumulates the
    EF residual: the *sum* of dequantized updates tracks the sum of true
    updates much closer than independent quantization does."""
    transport.reset_array_codec_state()
    rng = np.random.default_rng(3)
    steps = [rng.standard_normal(4096).astype(np.float32) for _ in range(8)]
    total_true = np.zeros(4096, np.float32)
    total_ef = np.zeros(4096, np.float32)
    total_plain = np.zeros(4096, np.float32)
    for s in steps:
        total_true += s
        ef_val, _ = transport.decode_payload(
            transport.encode_payload(s, name="ef-global"))
        total_ef += ef_val
        plain_val, _ = transport.decode_payload(
            transport.encode_payload(s))            # no name -> no EF
        total_plain += plain_val
    err_ef = float(np.abs(total_ef - total_true).mean())
    err_plain = float(np.abs(total_plain - total_true).mean())
    assert err_ef < err_plain
    transport.reset_array_codec_state()


def test_int8_reencode_of_aged_out_digest_is_deterministic(int8_codec):
    """Once a digest's replay blob ages out of the bounded caches, its
    re-encode must not run through error feedback again: the residual would
    advance twice for already-shipped content, and every re-encode would
    produce different bytes for one digest."""
    transport.reset_array_codec_state()
    rng = np.random.default_rng(11)
    arrs = [rng.standard_normal(4096).astype(np.float32) for _ in range(6)]
    for a in arrs:                           # 6 digests > _EF_REPLAY_KEEP=4
        transport.encode_payload(a, name="age")
    residual_before = transport._EF["age"].ef.residual.copy()
    b1 = transport.encode_payload(arrs[0], name="age")   # aged-out digest
    np.testing.assert_array_equal(
        transport._EF["age"].ef.residual, residual_before)  # no re-advance
    got, _ = transport.decode_payload(b1)
    bound = float(np.abs(arrs[0]).max()) / 127 + 1e-9
    assert float(np.abs(got - arrs[0]).max()) <= bound   # one-step contract
    transport.reset_array_codec_state()


def test_processes_worker_dead_at_dispatch_raises_workerdied(monkeypatch):
    """A worker that dies between checkout and dispatch makes the pipe send
    raise EPIPE; that must surface as WorkerDiedError (and mark the worker
    unhealthy so the pool self-heals), not complete the handle with neither
    run nor error. The checkout liveness filter is disabled to model the
    race deterministically."""
    from repro.core.backends import processes as proc_mod
    rc.plan("processes", workers=1)
    try:
        pid = value(future(lambda: os.getpid()))
        os.kill(pid, 9)
        deadline = time.time() + 10
        while time.time() < deadline and _pid_alive(pid):
            time.sleep(0.05)
        monkeypatch.setattr(proc_mod._Worker, "alive", lambda self: True)
        with pytest.raises(rc.WorkerDiedError):
            value(future(lambda: 1))
        monkeypatch.undo()
        assert value(future(lambda: 2)) == 2     # pool self-healed
    finally:
        rc.shutdown()


def test_bfloat16_arrays_ship_and_digest():
    """ml_dtypes bfloat16 numpy arrays do not export the buffer protocol;
    digesting and raw-shipping them must go through the uint8 view instead
    of crashing at future creation."""
    import jax.numpy as jnp
    xb = np.asarray(jnp.asarray(np.arange(20_000, dtype=np.float32) / 7,
                                jnp.bfloat16))
    assert content_digest(xb) is not None
    blob = transport.encode_payload(xb)
    assert blob[0] == transport.P_RAWARR
    got, cacheable = transport.decode_payload(blob)
    assert cacheable
    assert got.dtype == xb.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(xb, np.float32))


def test_codec_toggle_between_creation_and_dispatch_respects_digest():
    """A PayloadSource captures the codec its digest folded in at future
    creation: toggling set_array_codec before a (lazy) dispatch must not
    cache a wrong-codec blob under that digest."""
    from repro.core.globals_capture import extract_payload_refs
    x = np.random.default_rng(10).standard_normal(20_000).astype(np.float32)
    refd, sources = extract_payload_refs({"x": x}, backend="cluster")
    (digest,) = sources
    transport.set_array_codec("int8")            # toggle after creation
    try:
        blob = sources[digest].encode()
        assert blob[0] == transport.P_RAWARR     # creation-time codec wins
        got, _ = transport.decode_payload(blob)
        np.testing.assert_array_equal(got, x)
    finally:
        transport.set_array_codec("raw")


def test_encode_backfill_maps_encode_failure_to_nak():
    from repro.core.backends.blobstore import encode_backfill

    class Boom:
        def encode(self):
            raise RuntimeError("unpicklable mid-flight")

    assert encode_backfill(None) is None         # source gone -> nak
    assert encode_backfill(Boom()) is None       # encode failure -> nak


def test_non_float_arrays_ship_raw_and_lossless():
    x = np.arange(20000, dtype=np.int64)
    blob = transport.encode_payload(x)
    assert blob[0] == transport.P_RAWARR
    got, cacheable = transport.decode_payload(blob)
    assert cacheable
    np.testing.assert_array_equal(got, x)
    assert not got.flags.writeable


def test_int8_replay_is_byte_identical_after_content_advances(int8_codec):
    """One digest must decode identically everywhere: a backfill re-encode
    of an *older* digest — after the same global name advanced to new
    content and moved the EF residual — must replay the original bytes,
    not re-quantize (and must not advance the residual)."""
    transport.reset_array_codec_state()
    rng = np.random.default_rng(6)
    a = rng.standard_normal(8192).astype(np.float32)
    b = rng.standard_normal(8192).astype(np.float32)
    blob_a1 = transport.encode_payload(a, name="g")
    blob_b1 = transport.encode_payload(b, name="g")   # residual advances
    blob_a2 = transport.encode_payload(a, name="g")   # backfill of old digest
    blob_b2 = transport.encode_payload(b, name="g")
    assert blob_a2 == blob_a1
    assert blob_b2 == blob_b1
    transport.reset_array_codec_state()


def test_large_compressible_pickle_payloads_ship_zlibbed():
    """Non-array payloads travel out-of-band (no frame-layer zlib pass), so
    compressible pickles ≥64 KiB compress at the payload-codec layer."""
    val = {"toks": ["token-%d" % (i % 100) for i in range(20_000)]}
    raw = len(pickle.dumps(val, pickle.HIGHEST_PROTOCOL))
    blob = transport.encode_payload(val)
    assert blob[0] == transport.P_ZPICKLE
    assert len(blob) < raw / 2
    got, cacheable = transport.decode_payload(blob)
    assert got == val
    assert not cacheable


def test_pickle_payloads_round_trip():
    val = {"k": list(range(6000))}
    blob = transport.encode_payload(val, pickled=None)
    assert blob[0] == transport.P_PICKLE
    got, cacheable = transport.decode_payload(blob)
    assert got == val
    assert not cacheable                     # mutable: fresh per task


# --------------------------------------------------------------------------
# Zero-copy OOB frames
# --------------------------------------------------------------------------

def test_array_frames_ship_out_of_band():
    arr = np.random.default_rng(5).standard_normal(1 << 15) \
        .astype(np.float32)
    payload = ("result", 9, arr)
    blob = transport.encode_frame(payload)
    assert blob[8] == 2                      # OOB frame codec
    # framing overhead stays tiny: no pickle copy of the array body
    assert len(blob) < arr.nbytes + 4096

    a, b = socket.socketpair()
    transport.send_frame(a, payload)
    got = transport.recv_frame(b)
    assert got[0] == "result" and got[1] == 9
    np.testing.assert_array_equal(got[2], arr)

    transport.send_frame(a, payload)         # and through the select path
    reader = transport.FrameReader(b)
    frames = []
    while not frames:
        frames += reader.feed()
    np.testing.assert_array_equal(frames[0][2], arr)
    a.close()
    b.close()


def test_empty_array_frame_round_trips():
    """An empty ndarray pickles to a 0-byte out-of-band PickleBuffer; the
    sendmsg scatter loop must not spin on the zero-length view (it used to
    livelock holding send_lock)."""
    a, b = socket.socketpair()
    a.settimeout(10)
    b.settimeout(10)
    payload = ("result", 3, np.empty((0,), np.float32),
               np.arange(4, dtype=np.float32))
    transport.send_frame(a, payload)
    got = transport.recv_frame(b)
    assert got[0] == "result" and got[1] == 3
    assert got[2].size == 0 and got[2].dtype == np.float32
    np.testing.assert_array_equal(got[3], np.arange(4, dtype=np.float32))
    a.close()
    b.close()


def test_frame_reader_bulk_path_reassembles_dripped_large_frame():
    """Once a large frame's header is parsed, the reader switches to
    preallocated recv_into; drip-fed chunks still reassemble exactly."""
    a, b = socket.socketpair()
    body = os.urandom(300_000)               # incompressible: raw codec
    blob = transport.encode_frame(("task", 1, body))
    reader = transport.FrameReader(b)
    out = []
    for i in range(0, len(blob), 8192):      # one feed per delivered chunk
        a.sendall(blob[i:i + 8192])
        out += reader.feed()
    assert out == [("task", 1, body)]
    assert reader._bulk is None and not reader._buf
    a.close()
    b.close()


# --------------------------------------------------------------------------
# End-to-end: cache hits, invalidation, eviction/backfill, self-heal
# --------------------------------------------------------------------------

BIG_N = 200_000                              # 800 KB of float32


@pytest.fixture
def cluster1():
    rc.plan("cluster", workers=1)
    yield rc.active_backend()
    rc.shutdown()


def test_repeated_future_map_hits_the_blob_cache(cluster1):
    big = np.sin(np.arange(BIG_N, dtype=np.float32))
    expected = float(np.abs(big).sum())
    tol = BIG_N * float(np.abs(big).max()) / 127

    transport.reset_wire_stats()
    out1 = future_map(lambda i: float(np.abs(big).sum()) + i, [0, 1])
    first = transport.wire_stats()["bytes_sent"]
    out2 = future_map(lambda i: float(np.abs(big).sum()) + i, [2, 3])
    second = transport.wire_stats()["bytes_sent"] - first

    for got, off in zip(out1 + out2, [0, 1, 2, 3]):
        assert abs(got - (expected + off)) <= tol
    # acceptance: >=5x fewer bytes on the wire once the array is cached
    assert first >= 5 * max(second, 1), (first, second)


def test_empty_array_result_round_trips_on_cluster(cluster1):
    """End-to-end regression for the zero-length OOB view livelock: a task
    result containing an empty ndarray must come back (the worker's send
    used to spin forever, starving its heartbeat until the driver declared
    it dead)."""
    got = value(future(lambda: np.empty((0,), np.float32)))
    assert np.asarray(got).size == 0


def test_ensure_refs_surfaces_nak_as_channel_error():
    """A driver that cannot serve a digest (source gone, or encode failed)
    naks; the worker must turn that into a ChannelError for the task
    instead of waiting forever."""
    from repro.core.backends.worker import ensure_refs
    from repro.core.errors import ChannelError
    store = BlobStore()
    digest = b"n" * 16
    with pytest.raises(ChannelError, match=digest.hex()[:12]):
        ensure_refs(store, [digest], lambda d: None,
                    lambda: ("nak", digest))


def test_mutating_a_global_between_futures_invalidates_the_digest(cluster1):
    data = list(range(8000))                 # mutable: deep-copied, pickled
    v1 = value(future(lambda: sum(data)))
    assert v1 == sum(range(8000))
    data[0] = 10_000                         # mutate -> new content digest
    transport.reset_wire_stats()
    v2 = value(future(lambda: sum(data)))
    assert v2 == v1 + 10_000                 # fresh payload was shipped
    assert transport.wire_stats()["bytes_sent"] > len(pickle.dumps(data)) / 2


def test_eviction_triggers_need_backfill():
    """Worker blob store bounded to ~1.5 payloads: shipping A, then B, then
    A again forces the ("need", digest) path; values stay correct."""
    a = np.arange(50_000, dtype=np.int64)            # 400 KB, lossless codec
    b = np.arange(50_000, 100_000, dtype=np.int64)
    rc.plan("cluster", workers=1, blob_store_bytes=600_000)
    try:
        assert value(future(lambda: int(a[-1]))) == 49_999
        assert value(future(lambda: int(b[-1]))) == 99_999   # evicts a
        assert value(future(lambda: int(a[0]) + int(a[-1]))) == 49_999
        assert value(future(lambda: int(b[0]))) == 50_000
    finally:
        rc.shutdown()


def test_task_refs_exceeding_store_bound_survive_via_pinning():
    """One task whose refs collectively exceed the worker store bound must
    not thrash: the backfill put for one ref would otherwise evict its
    sibling mid-task (crash/respawn loop). Pinning lets the store exceed
    its bound by the task's working set."""
    a = np.arange(50_000, dtype=np.int64)            # 400 KB each
    b = np.arange(50_000, dtype=np.int64) * 2
    rc.plan("cluster", workers=1, blob_store_bytes=600_000)
    try:
        assert value(future(lambda: int(a[1]) + int(b[1]))) == 3
        assert value(future(lambda: int(a[2]) + int(b[2]))) == 6
    finally:
        rc.shutdown()


def test_self_healed_worker_starts_with_cold_cache(cluster1):
    big = np.arange(100_000, dtype=np.int64)         # 800 KB lossless
    assert value(future(lambda: int(big[-1]))) == 99_999
    transport.reset_wire_stats()
    assert value(future(lambda: int(big[-1]))) == 99_999     # cache hit
    hit = transport.wire_stats()["bytes_sent"]
    assert hit < 100_000

    with pytest.raises(rc.WorkerDiedError):
        value(future(lambda: os._exit(31)))          # kill; pool self-heals

    transport.reset_wire_stats()
    assert value(future(lambda: int(big[-1]))) == 99_999
    cold = transport.wire_stats()["bytes_sent"]
    assert cold > big.nbytes / 2                     # full re-ship happened


def test_payload_refs_only_split_large_globals():
    small = np.arange(16, dtype=np.float32)
    big = np.arange(PAYLOAD_REF_THRESHOLD, dtype=np.float32)
    from repro.core.globals_capture import extract_payload_refs
    refd, sources = extract_payload_refs(
        {"small": small, "big": big, "n": 3}, backend="cluster")
    assert refd["small"] is small and refd["n"] == 3
    assert isinstance(refd["big"], PayloadRef)
    assert set(sources) == {refd["big"].digest}


def test_unpicklable_global_still_raises_at_creation():
    sock_obj = socket.socket()
    try:
        rc.plan("processes", workers=1)
        with pytest.raises(rc.NonExportableObjectError, match="sock"):
            future(lambda: sock_obj.fileno())
    finally:
        sock_obj.close()
        rc.shutdown()


# --------------------------------------------------------------------------
# Conformance: by default a shipped float32 global is bit-exact on every
# external-process backend (backend transparency); with the int8 codec
# opted in, it is dequantized within the documented bound
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend_name", ["processes", "cluster"])
def test_shipped_float_global_is_lossless_by_default(backend_name):
    x = np.random.default_rng(7).standard_normal(40_000).astype(np.float32)
    rc.plan(backend_name, workers=1)
    try:
        got = value(future(lambda: x + 0.0))
        np.testing.assert_array_equal(np.asarray(got), x)
    finally:
        rc.shutdown()


@pytest.mark.parametrize("backend_name", ["processes", "cluster"])
def test_shipped_float_global_error_bounded_with_int8_opt_in(
        backend_name, int8_codec):
    # same content as the lossless test above, on purpose: digests fold the
    # codec in, so the raw blob cached there cannot be replayed here
    x = np.random.default_rng(7).standard_normal(40_000).astype(np.float32)
    rc.plan(backend_name, workers=1)
    try:
        got = value(future(lambda: x + 0.0))
        bound = float(np.abs(x).max()) / 127 + 1e-9
        assert float(np.abs(np.asarray(got) - x).max()) <= bound
    finally:
        rc.shutdown()


# --------------------------------------------------------------------------
# Warm backend pool across plan() changes
# --------------------------------------------------------------------------

def test_replan_reuses_live_cluster_workers():
    rc.plan("cluster", workers=2)
    b1 = rc.active_backend()
    pids = sorted(b1.worker_pids())
    rc.plan("threads", workers=2)
    assert value(future(lambda: 1)) == 1
    rc.plan("cluster", workers=2)
    b2 = rc.active_backend()
    assert b2 is b1                          # no cold start
    assert sorted(b2.worker_pids()) == pids  # the same live workers
    assert value(future(lambda: 2)) == 2
    rc.shutdown()


def test_replan_keeps_worker_blob_caches_warm():
    big = np.arange(120_000, dtype=np.int64)
    rc.plan("cluster", workers=1)
    try:
        assert value(future(lambda: int(big[0]))) == 0   # ships the payload
        rc.plan("threads", workers=1)
        rc.plan("cluster", workers=1)
        transport.reset_wire_stats()
        assert value(future(lambda: int(big[1]))) == 1
        # the re-attached worker still holds the blob: no re-ship
        assert transport.wire_stats()["bytes_sent"] < 100_000
    finally:
        rc.shutdown()


def test_explicit_shutdown_really_tears_down_the_pool():
    rc.plan("cluster", workers=1)
    pids = rc.active_backend().worker_pids()
    rc.plan("sequential")                    # parks the cluster backend
    rc.shutdown()                            # kills parked backends too
    deadline = time.time() + 10
    while time.time() < deadline:
        if not any(_pid_alive(p) for p in pids):
            break
        time.sleep(0.05)
    assert not any(_pid_alive(p) for p in pids)


def _pid_alive(pid) -> bool:
    try:
        os.kill(pid, 0)
    except (ProcessLookupError, TypeError):
        return False
    except PermissionError:
        return True
    return True


def test_replan_different_spec_same_port_flushes_warm_pool():
    """A parked cluster backend keeps its listener bound; re-planning to a
    *different* cluster spec on the same explicit port must flush the warm
    pool and retry instead of dying with EADDRINUSE at future creation."""
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    rc.plan("cluster", workers=1, port=port)
    try:
        assert value(future(lambda: 1)) == 1
        rc.plan("threads", workers=1)            # parks the cluster backend
        rc.plan("cluster", workers=2, port=port)  # different spec, same port
        assert value(future(lambda: 2)) == 2
    finally:
        rc.shutdown()


def test_dispatch_encode_failure_fails_future_not_worker(cluster1,
                                                         monkeypatch):
    """A payload encode failure at dispatch must fail that future with the
    real error and return the healthy worker to the pool — not leak the
    checked-out worker or complete the handle with neither run nor error."""
    from repro.core.backends import blobstore
    big = np.arange(60_000, dtype=np.int64)

    def boom(self):
        raise RuntimeError("encode exploded")

    monkeypatch.setattr(blobstore.PayloadSource, "encode", boom)
    f = future(lambda: int(big[0]))
    with pytest.raises(RuntimeError, match="encode exploded"):
        value(f)
    monkeypatch.undo()
    assert value(future(lambda: int(big[1]))) == 1   # worker still usable


def test_different_spec_is_not_reused():
    rc.plan("cluster", workers=1)
    b1 = rc.active_backend()
    rc.plan("cluster", workers=2)            # different spec -> new backend
    b2 = rc.active_backend()
    assert b2 is not b1
    rc.shutdown()


def test_nested_backend_is_cached_and_torn_down():
    seq = plan_mod.spec("threads", workers=1)
    with plan_mod.use_nested_stack((seq,)):
        a = plan_mod.active_backend()
        assert plan_mod.active_backend() is a    # cached on the TLS entry
    with plan_mod.use_nested_stack((seq,)):
        assert plan_mod.active_backend() is not a   # fresh per context
