"""Per-architecture smoke tests: reduced config, one forward + train-ish
step on CPU, asserting output shapes and no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, get_arch
from repro.models import Model

B, S = 2, 32


def _batch(cfg, key):
    kt, kl, kv, kf = jax.random.split(key, 4)
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.frontend_dim))
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
        batch["positions"] = jnp.stack([pos, pos, pos])
        batch["vision_embeds"] = jax.random.normal(kv, (B, 4, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_forward_and_grad_step(arch):
    cfg = get_arch(arch, smoke=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(model.apply)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/inf in logits"

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    # one SGD step must reduce nothing catastrophic (loss finite after)
    params2 = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = jax.jit(model.loss)(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", [a for a in all_archs()
                                  if get_arch(a).decode_capable])
def test_decode_step_matches_prefill(arch):
    """Greedy decode consistency: running S tokens through decode_step one
    at a time must match the full-sequence forward (same final logits)."""
    cfg = get_arch(arch, smoke=True)
    if cfg.rope_kind == "mrope":
        pytest.skip("mrope decode uses text-position fast path; covered by "
                    "shape test below")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                              cfg.vocab_size)
    full_logits, _ = model.apply(params, {"tokens": toks})

    cache = model.init_cache(B, max_seq=16, dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    for t in range(8):
        logits, cache = step(params, cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", [a for a in all_archs()
                                  if get_arch(a).decode_capable])
def test_decode_step_shapes(arch):
    cfg = get_arch(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, max_seq=16, dtype=jnp.float32)
    logits, new_cache = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) \
        == jax.tree_util.tree_structure(new_cache)


def test_cell_applicability_matrix():
    """31 runnable cells of 40 (DESIGN.md §6)."""
    runnable = 0
    for arch in all_archs():
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            ok, why = cfg.supports(shape)
            runnable += ok
            if not ok:
                assert why
    assert runnable == 31
