"""Map-reduce layer: chunking/load balancing, ordering, RNG invariance."""

import jax
import pytest
from _hypothesis_shim import given, settings, st

import repro.core as rc
from repro.core import (future_map, future_map_chunked_lazy, future_lapply)
from repro.core.mapreduce import _chunk_slices


def test_chunk_slices_partition_exactly():
    for n in (0, 1, 7, 10, 64):
        for c in (1, 2, 3, 10, 100):
            sl = _chunk_slices(n, c) if n else []
            flat = [i for r in sl for i in r]
            assert flat == list(range(n))


@given(n=st.integers(0, 40), chunks=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_map_equals_list_comprehension(n, chunks):
    xs = list(range(n))
    assert future_map(lambda v: v * 3 + 1, xs, chunks=chunks) \
        == [v * 3 + 1 for v in xs]


def test_results_ordered_despite_uneven_runtimes():
    rc.plan("threads", workers=3)
    import time

    def slow_for_small(x):
        time.sleep(0.05 if x < 2 else 0.0)
        return x

    assert future_map(slow_for_small, list(range(6)), chunks=6) \
        == list(range(6))


def test_rng_invariant_to_chunking_and_backend():
    def draw(x, key):
        return float(jax.random.normal(key, ()))

    rc.set_session_seed(7)
    ref = future_map(draw, [0] * 6, seed=True, chunks=1)

    for backend, kw in [("threads", {"workers": 2}),
                        ("processes", {"workers": 2})]:
        rc.plan(backend, **kw)
        rc.set_session_seed(7)
        for chunks in (1, 2, 6):
            got = future_map(draw, [0] * 6, seed=True, chunks=chunks)
            assert got == ref, (backend, chunks)
        rc.shutdown()


def test_lazy_merge_construction_matches():
    xs = list(range(9))
    assert future_map_chunked_lazy(lambda v: v - 1, xs, chunks=2) \
        == [v - 1 for v in xs]


def test_lapply_argument_order():
    assert future_lapply([1, 2], lambda v: v * 10) == [10, 20]


def test_empty_input():
    assert future_map(lambda v: v, []) == []


def test_future_map_straggler_does_not_stall_dispatch():
    """A slow early chunk must not stall dispatch of later chunks behind
    the ordered-result buffer (regression: the stream sugar's default
    in-flight cap introduced a head-of-line stall the eager frontend
    never had)."""
    import threading
    import time

    rc.plan("threads", workers=2)
    release = threading.Event()
    lock = threading.Lock()
    started = []

    def elem(x):
        with lock:
            started.append(x)
        if x == 0:
            release.wait(10)             # chunk 0 is the straggler
        return x

    result = []
    t = threading.Thread(
        target=lambda: result.append(future_map(elem, list(range(6)),
                                                chunks=6)))
    t.start()
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline:
        with lock:
            if len(started) == 6:
                break
        time.sleep(0.01)
    with lock:
        n_before_release = len(started)
    release.set()
    t.join(10)
    rc.shutdown()
    assert n_before_release == 6         # all chunks ran past the straggler
    assert result and result[0] == list(range(6))


def test_rng_misuse_warning():
    """Undeclared RNG use inside a future warns (paper §parallel RNG)."""
    from repro.core import rng

    def draws_without_seed():
        return float(rng.normal(jax.random.PRNGKey(0), ()))

    with pytest.warns(rc.RNGMisuseWarning):
        rc.value(rc.future(draws_without_seed))
