"""Deterministic fault injection for the TCP cluster stack.

:class:`HarnessLauncher` wraps a real :class:`~repro.core.backends.
launchers.Launcher` and records every :class:`WorkerProc` it hands to the
driver, so tests can kill / stall / partition a *chosen* worker at a
*chosen* moment — deterministic chaos instead of hoping a kill lands
mid-dispatch.

The synchronization idiom for "kill mid-task": the task body writes its own
pid into a marker file and then blocks; :meth:`HarnessLauncher.
kill_on_pidfile` arms a watcher thread that SIGKILLs exactly that worker
the moment the marker appears. The retry of the chunk sees the marker and
returns — so the kill is guaranteed to land mid-task, on the right worker,
on every run.

The harness is identity-hashable, so it can ride inside
``plan("cluster", hosts=2, launcher=harness)`` spec kwargs like any other
launcher.
"""

from __future__ import annotations

import os
import signal
import socket
import threading
import time

from repro.core.backends.launchers import Launcher, LocalLauncher, WorkerProc

_TLS_LOCK = threading.Lock()
_TLS_CFG = None


def ephemeral_tls():
    """Process-cached self-signed TLS material for tests: one openssl
    keygen per pytest run, shared by every TLS test (the cert is valid
    for days; generating per-test would dominate suite time)."""
    global _TLS_CFG
    with _TLS_LOCK:
        if _TLS_CFG is None:
            import tempfile

            from repro.core.backends.transport import \
                generate_self_signed_cert
            _TLS_CFG = generate_self_signed_cert(
                tempfile.mkdtemp(prefix="repro-test-tls-"))
        return _TLS_CFG


class HarnessLauncher(Launcher):
    """Launcher wrapper that remembers everything it launched and can hurt
    any of it on command."""

    def __init__(self, inner: "Launcher | None" = None):
        self.inner = inner or LocalLauncher()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        #: every WorkerProc ever launched, in launch order (incl. dead ones)
        self.procs: list[WorkerProc] = []

    # -- Launcher protocol --------------------------------------------------

    @property
    def local_only(self):
        return getattr(self.inner, "local_only", False)

    def launch(self, host, driver_addr, *, tag=None,
               extra_env=()) -> WorkerProc:
        wp = self.inner.launch(host, driver_addr, tag=tag,
                               extra_env=extra_env)
        with self._cv:
            self.procs.append(wp)
            self._cv.notify_all()
        return wp

    def describe(self) -> str:
        return f"harness({self.inner.describe()})"

    # -- introspection ------------------------------------------------------

    @property
    def launches(self) -> int:
        with self._lock:
            return len(self.procs)

    def alive(self) -> "list[WorkerProc]":
        with self._lock:
            return [wp for wp in self.procs if wp.poll() is None]

    def wait_launches(self, n: int, timeout: float = 30.0
                      ) -> "list[WorkerProc]":
        """Block until at least ``n`` workers have been launched."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while len(self.procs) < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(
                        f"{len(self.procs)}/{n} launches within {timeout}s")
                self._cv.wait(left)
            return list(self.procs)

    def by_pid(self, pid: int) -> "WorkerProc | None":
        with self._lock:
            for wp in self.procs:
                if wp.pid == pid:
                    return wp
        return None

    # -- chaos --------------------------------------------------------------

    def kill(self, wp: WorkerProc) -> None:
        """SIGKILL: hard node failure."""
        wp.kill()

    def stall(self, wp: WorkerProc) -> None:
        """SIGSTOP: alive socket, wedged process — heartbeat loss without
        EOF (the driver must detect it via heartbeat_timeout)."""
        os.kill(wp.pid, signal.SIGSTOP)

    def resume(self, wp: WorkerProc) -> None:
        try:
            os.kill(wp.pid, signal.SIGCONT)
        except ProcessLookupError:
            pass

    def delay(self, wp: WorkerProc, seconds: float) -> threading.Thread:
        """Delayed delivery: freeze the worker (SIGSTOP) now and resume it
        (SIGCONT) after ``seconds`` — a *slow* peer rather than a dead
        one. With ``seconds`` past the driver's heartbeat timeout this
        pins the fetch-races-reconstruction window: the driver declares
        the holder dead and starts rebuilding while the process (and its
        peer server, with the original bytes) comes back mid-recovery.
        Returns the resume-timer thread (daemon; join to sync on it)."""
        os.kill(wp.pid, signal.SIGSTOP)
        timer = threading.Timer(seconds, self.resume, args=(wp,))
        timer.daemon = True
        timer.name = "harness-delay-resume"
        timer.start()
        return timer

    def partition(self, backend, wp: WorkerProc) -> bool:
        """Sever the driver<->worker TCP stream without touching the
        process: the driver sees EOF/heartbeat loss, the worker sees EOF —
        a network partition, as far as either end can tell."""
        w = self._sock_worker(backend, wp)
        if w is None or w.sock is None:
            return False
        try:
            w.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        return True

    def _sock_worker(self, backend, wp: WorkerProc):
        with backend._pool_cv:
            for w in backend._all:
                if w.proc is wp or w.meta.get("pid") == wp.pid:
                    return w
        return None

    # -- deterministic mid-task kill ----------------------------------------

    def busy_proc(self, backend, timeout: float = 10.0) -> WorkerProc:
        """Block until some launched worker is busy; return its proc."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with backend._pool_cv:
                for w in backend._all:
                    if w.busy is not None and w.proc is not None:
                        return w.proc
            time.sleep(0.01)
        raise TimeoutError("no launched worker went busy "
                           f"within {timeout}s")

    def kill_busy(self, backend, timeout: float = 10.0) -> WorkerProc:
        wp = self.busy_proc(backend, timeout)
        self.kill(wp)
        return wp

    def kill_on_pidfile(self, path: str, timeout: float = 30.0
                        ) -> threading.Thread:
        """Arm a watcher: the moment ``path`` exists and contains a pid
        (written by the task body right before it blocks), SIGKILL that
        worker. Returns the watcher thread; join it and check
        ``thread.killed`` (the WorkerProc) to assert the kill landed."""
        def _watch():
            deadline = time.monotonic() + timeout
            pid = None
            while time.monotonic() < deadline:
                try:
                    with open(path) as fh:
                        pid = int(fh.read().strip())
                    break
                except (OSError, ValueError):
                    time.sleep(0.005)
            if pid is None:
                return
            while time.monotonic() < deadline:
                wp = self.by_pid(pid)
                if wp is not None:
                    self.kill(wp)
                    thread.killed = wp
                    return
                time.sleep(0.005)

        thread = threading.Thread(target=_watch, daemon=True,
                                  name="harness-kill-on-pidfile")
        thread.killed = None
        thread.start()
        return thread
