"""Streaming frontend (`core/stream.py`): bounded in-flight backpressure,
admission-controlled dispatch, unbounded sources, RNG invariance across
``max_in_flight``, and mid-stream fault retry.

The value/ordering/error conformance of ``stream`` across every backend
(including the ``cluster+local-launcher`` row) lives in the matrix in
``test_conformance.py``; this file asserts the *streaming* properties the
eager ``future_map`` never had.
"""

import itertools
import threading
import time

import pytest

import repro.core as rc
from _cluster_harness import HarnessLauncher
from repro.core import future_map, stream
from test_conformance import BACKENDS, IDS, resolve_backend_kwargs

_FAST = dict(heartbeat_interval=0.1, heartbeat_timeout=3.0,
             relaunch_backoff=0.05, relaunch_backoff_cap=0.2)


@pytest.fixture(params=BACKENDS, ids=IDS)
def backend(request):
    _id, name, kw = request.param
    rc.plan(name, **resolve_backend_kwargs(kw))
    yield name
    rc.shutdown()


# --------------------------------------------------------------------------
# the stream conformance row, across the full backend matrix
# --------------------------------------------------------------------------

def test_stream_pipeline_stages_full_matrix(backend):
    """filter -> batch -> map -> collect, generator input, on every
    backend (incl. launched cluster workers)."""
    s = stream(i for i in itertools.islice(itertools.count(), 24))
    got = (s.filter(lambda v: v % 3 != 0)
           .batch(4)
           .map(sum, chunk=2)
           .collect(ordered=True))
    kept = [v for v in range(24) if v % 3 != 0]
    want = [sum(kept[i:i + 4]) for i in range(0, len(kept), 4)]
    assert got == want
    assert s.stats["peak_in_flight"] <= s.stats["max_in_flight"]


def test_stream_unordered_collect_is_same_multiset(backend):
    xs = list(range(20))
    got = stream(xs).map(lambda v: v * v, chunk=3).collect(ordered=False)
    assert sorted(got) == [v * v for v in xs]


# --------------------------------------------------------------------------
# backpressure: peak in-flight <= max_in_flight, by counting harnesses
# --------------------------------------------------------------------------

def test_backpressure_bounds_concurrency_threads():
    """Counting harness (shared memory): with ``max_in_flight`` below the
    worker count, the number of *simultaneously executing* bodies — not
    just the pump's own accounting — stays within the bound."""
    rc.plan("threads", workers=4)
    lock = threading.Lock()
    state = {"cur": 0, "peak": 0}

    def body(x):
        with lock:
            state["cur"] += 1
            state["peak"] = max(state["peak"], state["cur"])
        time.sleep(0.005)
        with lock:
            state["cur"] -= 1
        return x

    s = stream(range(40), max_in_flight=2)
    assert s.map(body).collect() == list(range(40))
    assert state["peak"] <= 2
    assert 0 < s.stats["peak_in_flight"] <= 2
    rc.shutdown()


def test_backpressure_bounds_concurrency_processes():
    """Counting harness (wall-clock spans): bodies report their execution
    windows; the maximum overlap across workers stays within
    ``max_in_flight`` even though more workers are available."""
    rc.plan("processes", workers=3)

    def body(x):
        import time as _t
        t0 = _t.time()
        _t.sleep(0.02)
        return (t0, _t.time())

    s = stream(range(12), max_in_flight=2)
    spans = s.map(body).collect()
    events = sorted([(t0, 1) for t0, _ in spans]
                    + [(t1, -1) for _, t1 in spans])
    cur = peak = 0
    for _, step in events:
        cur += step
        peak = max(peak, cur)
    assert peak <= 2
    assert s.stats["peak_in_flight"] <= 2
    rc.shutdown()


def test_admission_never_exceeds_cluster_idle_set():
    """On the cluster backend the pump admits through the driver's idle
    worker set: in-flight futures never exceed the live worker count even
    when ``max_in_flight`` is larger."""
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    assert backend.free_slots() == 2

    def body(v):
        import time as _t
        _t.sleep(0.02)       # long vs the dispatch loop: completions land
        return v + 1         # while the pump waits, not mid-admission

    s = stream(range(30), max_in_flight=16)
    assert s.map(body, chunk=3).collect() == [v + 1 for v in range(30)]
    # "in flight" = dispatched-not-yet-harvested, so completed futures
    # awaiting harvest count too — but admission keeps the peak near the
    # worker count (2 running + harvest lag), nowhere near the 16 cap
    assert s.stats["peak_in_flight"] <= 4
    assert backend.free_slots() == 2             # all returned to idle
    rc.shutdown()


# --------------------------------------------------------------------------
# unbounded / huge sources: O(in-flight) memory, never materialized
# --------------------------------------------------------------------------

def test_unbounded_generator_as_completed_breaks_cleanly():
    rc.plan("threads", workers=2)
    seen = []
    for v in stream(itertools.count()).map(lambda v: v, chunk=4) \
            .as_completed():
        seen.append(v)
        if len(seen) >= 50:
            break                        # GeneratorExit cancels the tail
    assert sorted(seen)[:4] == [0, 1, 2, 3]
    # the backend is still healthy after the abandoned stream
    assert rc.value(rc.future(lambda: "alive")) == "alive"
    rc.shutdown()


def test_million_element_generator_is_streamed_not_materialized():
    """The acceptance criterion: a 1M-element generator reduces with peak
    in-flight <= max_in_flight and the pump never pulls more than the
    in-flight window ahead of consumption (i.e. input is not
    materialized)."""
    rc.plan("threads", workers=2)
    n, chunk, mif = 1_000_000, 5_000, 4
    state = {"pulled": 0, "consumed": 0, "max_lead": 0}

    def source():
        for i in range(n):
            state["pulled"] += 1
            yield 1

    def note(a, b):
        state["consumed"] += chunk       # one completed chunk per fold step
        state["max_lead"] = max(state["max_lead"],
                                state["pulled"] - state["consumed"])
        return a + b

    s = stream(source(), max_in_flight=mif)
    got = (s.batch(chunk)                # 5k source elements -> one item
           .map(sum, chunk=1)           # one future per batch
           .reduce(note))               # fold batch sums as they complete
    assert got == n
    assert state["pulled"] == n                       # fully consumed...
    assert 0 < s.stats["peak_in_flight"] <= mif       # ...bounded in flight
    # never pulled more than the in-flight window + assembly slack ahead
    # (+1 chunk because reduce() seeds the fold without calling the op)
    assert state["max_lead"] <= (mif + 3) * chunk
    rc.shutdown()


# --------------------------------------------------------------------------
# RNG invariance across max_in_flight (the CMRG guarantee, streamed)
# --------------------------------------------------------------------------

def test_rng_invariant_to_max_in_flight_and_chunk():
    import jax

    def draw(x, key):
        return float(jax.random.normal(key, ()))

    rc.set_session_seed(11)
    ref = future_map(draw, [0] * 8, seed=True, chunks=1)

    for backend, kw in [("threads", {"workers": 2}),
                        ("processes", {"workers": 2})]:
        rc.plan(backend, **kw)
        for mif in (1, 3, 16):
            for chunk in (1, 3):
                rc.set_session_seed(11)
                got = (stream([0] * 8, max_in_flight=mif)
                       .map(draw, seed=True, chunk=chunk)
                       .collect(ordered=True))
                assert got == ref, (backend, mif, chunk)
        rc.shutdown()


def test_int_seed_offsets_element_indices_like_future_map():
    import jax

    def draw(x, key):
        return float(jax.random.normal(key, ()))

    rc.set_session_seed(3)
    ref = future_map(draw, [0] * 4, seed=7, chunks=2)
    rc.set_session_seed(3)
    got = stream([0] * 4).map(draw, seed=7, chunk=3).collect()
    assert got == ref


# --------------------------------------------------------------------------
# retries: FutureError-driven re-dispatch, mid-stream worker kill
# --------------------------------------------------------------------------

def test_stream_retries_dead_chunk_processes(tmp_path):
    rc.plan("processes", workers=2)
    marker = str(tmp_path / "chunk-died")

    def elem(x, _marker=marker):
        import os as _os
        if x == 3 and not _os.path.exists(_marker):
            open(_marker, "w").close()
            _os._exit(7)
        return x * 2

    s = stream(range(6), max_in_flight=2)
    assert s.map(elem, retries=2).collect() == [0, 2, 4, 6, 8, 10]
    assert s.stats["retried"] >= 1
    rc.shutdown()


def test_stream_retries_exhausted_raises():
    rc.plan("processes", workers=2)

    def die(x):
        import os as _os
        _os._exit(13)

    with pytest.raises(rc.WorkerDiedError):
        stream(range(4)).map(die, retries=1).collect()
    rc.shutdown()


@pytest.mark.launcher
def test_mid_stream_worker_kill_relaunch_and_retry(tmp_path):
    """A harness-injected SIGKILL lands mid-stream on the worker running a
    chosen element (deterministic: the body publishes its pid then
    blocks); the driver relaunches, the pump re-dispatches the chunk, and
    the stream completes correctly."""
    h = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=h, **_FAST)
    marker = str(tmp_path / "victim-pid")
    watcher = h.kill_on_pidfile(marker)

    def elem(x, _marker=marker):
        import os as _os
        import time as _time
        if x == 3 and not _os.path.exists(_marker):
            with open(_marker, "w") as fh:
                fh.write(str(_os.getpid()))
                fh.flush()
            while True:                  # stay mid-task until the kill lands
                _time.sleep(0.05)
        return x * 2

    s = stream(range(6), max_in_flight=2)
    assert s.map(elem, retries=2).collect() == [0, 2, 4, 6, 8, 10]
    assert s.stats["retried"] >= 1
    watcher.join(timeout=10)
    assert watcher.killed is not None
    # SIGKILL delivery is asynchronous: give the doomed process a moment
    # to actually die and be reaped before asserting on its exit status
    deadline = time.monotonic() + 10
    while watcher.killed.poll() is None and time.monotonic() < deadline:
        time.sleep(0.02)
    assert watcher.killed.poll() is not None
    rc.shutdown()


# --------------------------------------------------------------------------
# semantics edges
# --------------------------------------------------------------------------

def test_reduce_empty_and_init():
    assert stream([]).map(lambda v: v).reduce(lambda a, b: a + b,
                                              init=42) == 42
    with pytest.raises(ValueError):
        stream([]).map(lambda v: v).reduce(lambda a, b: a + b)
    assert stream([5]).map(lambda v: v).reduce(lambda a, b: a + b) == 5


def test_streams_are_immutable_and_chainable():
    base = stream(range(6))
    doubled = base.map(lambda v: v * 2)
    assert len(base._ops) == 0 and len(doubled._ops) == 1
    assert doubled.collect() == [0, 2, 4, 6, 8, 10]


def test_batch_validates():
    with pytest.raises(ValueError):
        stream([1]).batch(0)


def test_future_map_is_stream_sugar_same_results():
    """future_map's public contract is preserved by the sugar: ordering,
    chunk plan, retry kwarg and values match the streamed equivalent."""
    rc.plan("threads", workers=3)
    xs = list(range(17))
    assert future_map(lambda v: v - 1, xs, chunks=5) \
        == [v - 1 for v in xs]
    assert future_map(lambda v: v - 1, xs) == [v - 1 for v in xs]
    assert future_map(lambda v: v, []) == []
    rc.shutdown()


# --------------------------------------------------------------------------
# Byte-denominated backpressure: stream(..., max_in_flight_bytes=)
# --------------------------------------------------------------------------

def test_max_in_flight_bytes_bounds_admission():
    """Peak in-flight estimated bytes never exceeds the budget, and the
    stats expose both the budget and the observed peak."""
    import numpy as np
    rc.plan("threads", workers=4)
    arrs = [np.zeros(1 << 18) for _ in range(12)]        # 2 MiB each
    budget = 5 * (1 << 21)                               # 10 MiB
    s = stream(arrs, max_in_flight_bytes=budget)
    assert s.map(lambda a: float(a.sum())).collect(ordered=True) \
        == [0.0] * 12
    assert 0 < s.stats["peak_in_flight_bytes"] <= budget
    assert s.stats["max_in_flight_bytes"] == budget
    rc.shutdown()


def test_max_in_flight_bytes_progress_guarantee():
    """A chunk larger than the whole budget is still admitted — alone.
    Byte backpressure throttles to one-at-a-time, never wedges."""
    import numpy as np
    rc.plan("threads", workers=2)
    arrs = [np.zeros(1 << 18) for _ in range(3)]
    s = stream(arrs, max_in_flight_bytes=1024)           # tiny budget
    assert s.map(lambda a: a.shape[0]).collect(ordered=True) \
        == [1 << 18] * 3
    assert s.stats["peak_in_flight"] == 1
    rc.shutdown()


def test_max_in_flight_bytes_composes_with_count_bound():
    """Both bounds hold at once; small items hit the count bound, the
    byte peak stays under budget."""
    rc.plan("threads", workers=4)
    s = stream(iter(range(40)), max_in_flight=3,
               max_in_flight_bytes=1 << 20)
    assert sorted(s.map(lambda v: v + 1, chunk=4).collect()) \
        == [v + 1 for v in range(40)]
    assert s.stats["peak_in_flight"] <= 3
    assert s.stats["peak_in_flight_bytes"] <= 1 << 20
    rc.shutdown()
