"""Regression: stdout capture must be thread-aware — the main thread's
prints must NOT be swallowed while a threads-backend future is running
(found by examples/quickstart.py)."""

import time

import repro.core as rc
from repro.core import future, value


def test_main_thread_prints_not_swallowed(capsys):
    rc.plan("threads", workers=2)
    f = future(lambda: (time.sleep(0.3), print("from-future"), 7)[2])
    time.sleep(0.05)
    print("from-main-thread")            # emitted while the future runs
    assert value(f) == 7
    out = capsys.readouterr().out
    assert "from-main-thread" in out
    assert "from-future" in out          # relayed at value()
    rc.shutdown()


def test_nested_capture_on_same_thread(capsys):
    """sequential-inside-sequential: inner future's stdout must relay into
    the outer future's capture, then out to the caller."""
    def outer():
        print("outer-line")
        v = value(future(lambda: print("inner-line") or 5))
        return v

    assert value(future(outer)) == 5
    out = capsys.readouterr().out
    assert "outer-line" in out and "inner-line" in out


def test_router_uninstalls_cleanly(capsys):
    import sys
    from repro.core.conditions import _StdoutRouter
    value(future(lambda: print("x")))
    capsys.readouterr()
    assert not isinstance(sys.stdout, _StdoutRouter)
