"""Optimizer, checkpoint, data pipeline, trainer integration tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as rc
from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import Prefetcher, synth_batch
from repro.models import Model
from repro.optim import AdamWConfig, adamw
from repro.optim.compression import (ErrorFeedback, dequantize_int8,
                                     quantize_int8, topk_restore,
                                     topk_sparsify)
from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step


def test_adamw_reduces_loss_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, metrics = adamw.apply_updates(cfg, params, grads, state)
    assert float(loss(params)) < 0.1
    assert float(metrics["grad_norm"]) >= 0


def test_grad_clipping():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.apply_updates(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


def test_int8_roundtrip_error_bounded():
    x = np.random.default_rng(0).standard_normal(1000).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(x))
    back = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """EF must pass the sum of gradients through despite quantization."""
    ef = ErrorFeedback()
    rng = np.random.default_rng(1)
    total_in = np.zeros(64, np.float32)
    total_out = np.zeros(64, np.float32)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
        _, deq = ef.compress(g)
        total_in += np.asarray(g["w"])
        total_out += np.asarray(deq["w"])
    # residual is bounded => sums track each other
    assert np.abs(total_in - total_out).max() < 0.2


def test_topk_sparsify_roundtrip():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 8)))
    vals, idx, shape = topk_sparsify(x, frac=0.25)
    back = topk_restore(vals, idx, shape)
    kept = np.count_nonzero(np.asarray(back))
    assert kept == 16
    nz = np.asarray(back) != 0
    np.testing.assert_allclose(np.asarray(back)[nz], np.asarray(x)[nz])


def test_synth_batch_deterministic():
    cfg = get_arch("yi-9b", smoke=True)
    b1 = synth_batch(cfg, batch=2, seq=16, seed=5, step=3)
    b2 = synth_batch(cfg, batch=2, seq=16, seed=5, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synth_batch(cfg, batch=2, seq=16, seed=5, step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_prefetcher_order_and_content():
    cfg = get_arch("yi-9b", smoke=True)
    rc.plan("threads", workers=2)
    pf = Prefetcher(cfg, batch=2, seq=16, seed=9, prefetch=2)
    got = [pf.next_batch() for _ in range(4)]
    want = [synth_batch(cfg, batch=2, seq=16, seed=9, step=i)
            for i in range(4)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g["tokens"], w["tokens"])
    rc.shutdown()


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree_util.tree_map(lambda x: x + step, state))
    assert mgr.latest_step() == 30
    restored, step = mgr.restore(state)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["a"], np.float32),
                               np.asarray(state["a"]) + 30)
    assert restored["b"]["c"].dtype == jnp.bfloat16
    # retention: only 2 kept
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert sorted(kept) == ["step_00000020", "step_00000030"]


def test_async_checkpoint_overlaps(tmp_path):
    rc.plan("threads", workers=2)
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = {"w": jnp.ones((64, 64))}
    mgr.save(1, state)
    mgr.wait()
    assert mgr.latest_step() == 1
    rc.shutdown()


def test_trainer_loss_decreases(tmp_path):
    cfg = get_arch("xlstm-125m", smoke=True)
    tcfg = TrainerConfig(steps=30, batch=4, seq=32, log_every=10,
                         ckpt_every=15, ckpt_dir=str(tmp_path / "ckpt"))
    trainer = Trainer(cfg, tcfg,
                      AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30))
    state, history = trainer.run()
    assert history[-1]["loss"] < history[0]["loss"]
    assert trainer.ckpt.latest_step() == 30


def test_trainer_restart_from_checkpoint(tmp_path):
    """Fault-tolerance: a second trainer resumes from the survivor ckpt."""
    cfg = get_arch("xlstm-125m", smoke=True)
    ckpt_dir = str(tmp_path / "ckpt")
    tcfg = TrainerConfig(steps=20, batch=2, seq=16, log_every=5,
                         ckpt_every=10, ckpt_dir=ckpt_dir)
    t1 = Trainer(cfg, tcfg)
    state, _ = t1.init_or_restore()
    # run only to step 10 (simulate crash after first checkpoint)
    t1.tcfg = TrainerConfig(**{**tcfg.__dict__, "steps": 10})
    t1.run(state, start_step=0)

    t2 = Trainer(cfg, tcfg)
    state2, start = t2.init_or_restore()
    assert start == 10
    _, hist = t2.run(state2, start_step=start)
    assert hist[-1]["step"] == 20


def test_microbatch_accumulation_matches_full():
    cfg = get_arch("yi-9b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, batch=4, seq=16, seed=0, step=0).items()}
    opt = AdamWConfig(lr=1e-3, warmup_steps=0)
    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    a = jax.tree_util.tree_leaves(s1.params)[0]
    b = jax.tree_util.tree_leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_remat_policies_same_loss():
    cfg = get_arch("yi-9b", smoke=True)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, batch=2, seq=16, seed=0, step=0).items()}
    params = Model(cfg).init(jax.random.PRNGKey(0))
    losses = []
    for remat in ("none", "full", "dots"):
        model = Model(cfg, remat=remat)
        (loss, _), grads = jax.jit(jax.value_and_grad(
            model.loss, has_aux=True))(params, batch)
        losses.append(float(loss))
        gn = float(adamw.global_norm(grads))
        assert np.isfinite(gn)
    np.testing.assert_allclose(losses, losses[0], rtol=1e-6)
