"""Pipeline parallelism: pipelined loss == plain loss (subprocess with fake
devices so the main pytest process keeps its single-device view)."""

import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")   # no TPU probing in the sandbox
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.configs import get_arch
from repro.models import Model
from repro.data import synth_batch
from repro.train.pipeline import make_pipeline_loss, split_stage_params

cfg = get_arch("yi-9b", smoke=True)           # 2-layer uniform stack
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {k: jnp.asarray(v) for k, v in
         synth_batch(cfg, batch=4, seq=16, seed=0, step=0).items()}

plain_loss, _ = model.loss(params, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
pp_params = split_stage_params(params, 2)
loss_fn = make_pipeline_loss(model, mesh, microbatches=2, remat="none")
with set_mesh(mesh):
    pp_loss = jax.jit(loss_fn)(pp_params, batch)
print("plain", float(plain_loss), "pipeline", float(pp_loss))
np.testing.assert_allclose(float(pp_loss), float(plain_loss),
                           rtol=2e-4, atol=2e-4)

# gradients flow through the schedule (ppermute transpose)
g = jax.jit(jax.grad(loss_fn))(pp_params, batch)
gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
assert np.isfinite(gn) and gn > 0, gn
print("grad-ok", gn)
"""


def test_pipeline_matches_plain_loss():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo")
    if proc.returncode != 0 and \
            "PartitionId instruction is not supported" in proc.stderr:
        # partially-manual shard_map (manual 'pod', auto data/model) cannot
        # be SPMD-partitioned by this jax/XLA release — a platform
        # limitation, not a pipeline bug. Newer jax runs this to completion.
        pytest.skip("partial-auto shard_map unsupported by installed jax")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "grad-ok" in proc.stdout
