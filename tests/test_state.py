"""Shared-state subsystem: driver-hosted versioned KV with CAS/watch.

The battery beyond the conformance-matrix rows (test_conformance.py runs
the same state semantics on all six backends): the 8-worker cluster fold —
``state.update`` from 8 concurrent socket workers is the *exact* sequential
fold — raw-CAS contention accounting (every lost CAS corresponds to a real
interleaved commit), the SIGKILL-a-worker-mid-``update`` fault case under
the PR 4 harness (no lost update, no torn version), and watch fan-out.
Synchronization is on observable driver state (service stats, pid markers),
never sleeps.
"""

import time

import pytest

import repro.core as rc
from _cluster_harness import HarnessLauncher
from repro.core import future, gather, state, value

pytestmark = pytest.mark.state

#: fast-heal knobs (same as test_faults) so the fault case runs in seconds
_FAST = dict(heartbeat_interval=0.1, heartbeat_timeout=3.0,
             relaunch_backoff=0.05, relaunch_backoff_cap=0.2)


def _poll(pred, timeout=15.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.01)
    raise TimeoutError(f"{what} not reached within {timeout}s")


# --------------------------------------------------------------------------
# The acceptance fold: 8 concurrent cluster workers, zero lost updates
# --------------------------------------------------------------------------

def test_eight_cluster_workers_exact_fold():
    """state.update from 8 concurrent cluster workers yields the exact
    sequential fold: final value == total updates == final version."""
    rc.plan("cluster", workers=8)
    per_task = 4

    def body():
        from repro.core import state
        for _ in range(per_task):
            state.update("acc8", lambda v: (v or 0) + 1)
        return True

    fs = [future(body) for _ in range(8)]
    assert value(gather(fs)) == [True] * 8
    assert state.get("acc8") == 8 * per_task
    assert state.version("acc8") == 8 * per_task
    rc.shutdown()


def test_cas_loses_exactly_the_races_it_should():
    """Raw version-read + cas loops from 4 workers: every commit bumps the
    version exactly once (wins == final version), and every refused cas
    was a genuine race — the version it read had been overtaken."""
    rc.plan("cluster", workers=4)

    def body(i):
        from repro.core import state
        wins, attempts = 0, 0
        for _ in range(6):
            while True:
                ver = state.version("cas.k")
                attempts += 1
                ok, newver, _cur = state.cas("cas.k", ver, i)
                if ok:
                    assert newver == ver + 1       # never a torn version
                    wins += 1
                    break
        return wins, attempts

    got = value(gather([future(lambda i=i: body(i)) for i in range(4)]))
    total_wins = sum(w for w, _ in got)
    total_attempts = sum(a for _, a in got)
    assert total_wins == 4 * 6                     # nobody gave up a slot
    assert rc.state.version("cas.k") == total_wins  # one version per commit
    assert total_attempts >= total_wins            # losses only to races
    rc.shutdown()


def test_update_fn_reruns_are_invisible_in_history():
    """The RPC update loop may re-run fn under contention; the commit
    history is still one fold per update — observed via the service's
    cas_fail counter exceeding zero while value == version holds."""
    rc.plan("cluster", workers=4)

    def body():
        from repro.core import state
        for _ in range(8):
            state.update("rerun.acc", lambda v: (v or 0) + 1)
        return state.stats()["cas_retries"]

    retries = value(gather([future(body) for _ in range(4)]))
    assert state.get("rerun.acc") == 32
    assert state.version("rerun.acc") == 32
    assert all(r >= 0 for r in retries)
    rc.shutdown()


# --------------------------------------------------------------------------
# Fault: SIGKILL a worker mid-update — no lost update, no torn version
# --------------------------------------------------------------------------

def test_sigkill_mid_update_no_lost_update_no_torn_version(tmp_path):
    """A worker SIGKILLed while hammering state.update must not corrupt
    the service: its future fails with WorkerDiedError, every surviving
    update lands, and value == version (each commit was exactly one
    fold — a half-applied or double-applied update would break it)."""
    harness = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=harness, **_FAST)
    pidfile = str(tmp_path / "victim.pid")

    def victim(_p=pidfile):
        import os as _os
        import time as _time
        from repro.core import state
        with open(_p + ".tmp", "w") as fh:
            fh.write(str(_os.getpid()))
        _os.replace(_p + ".tmp", _p)          # pid visible only when complete
        deadline = _time.monotonic() + 20
        while _time.monotonic() < deadline:   # hammer until the kill lands
            state.update("kill.acc", lambda v: (v or 0) + 1)
        return "survived"

    def steady():
        from repro.core import state
        for _ in range(10):
            state.update("kill.acc", lambda v: (v or 0) + 1)
        return True

    fv = future(victim)
    watcher = harness.kill_on_pidfile(pidfile)
    others = [future(steady) for _ in range(3)]
    with pytest.raises(rc.WorkerDiedError):
        value(fv)
    watcher.join(timeout=30)
    assert watcher.killed is not None          # the kill landed mid-update
    assert value(gather(others)) == [True] * 3  # pool self-healed
    final = rc.state.get("kill.acc")
    assert rc.state.version("kill.acc") == final   # no torn version
    assert final >= 30                         # no lost surviving update
    rc.shutdown()


# --------------------------------------------------------------------------
# Watch fan-out
# --------------------------------------------------------------------------

def test_wait_fanout_one_put_releases_all_waiters():
    """Several parked cluster waiters are all released by one put — the
    driver's watch list fires every satisfied watch, not just one."""
    rc.plan("cluster", workers=4)

    def waiter():
        from repro.core import state
        val, ver = state.wait("fan.k", 1, timeout=30)
        return (val, ver)

    ws = [future(waiter) for _ in range(3)]
    svc = state.service()
    _poll(lambda: svc.stats()["watches"] >= 3, what="3 parked watchers")
    rc.state.put("fan.k", "fire")
    assert value(gather(ws)) == [("fire", 1)] * 3
    rc.shutdown()


def test_wait_min_version_skips_stale_values():
    """A waiter demanding min_version=2 ignores the v1 value and wakes on
    the second put with the v2 value."""
    rc.plan("cluster", workers=2)
    rc.state.put("mv.k", "old")                # version 1

    def waiter():
        from repro.core import state
        return state.wait("mv.k", 2, timeout=30)

    w = future(waiter)
    svc = state.service()
    _poll(lambda: svc.stats()["watches"] >= 1, what="parked watcher")
    rc.state.put("mv.k", "new")                # version 2
    assert value(w) == ("new", 2)
    rc.shutdown()


# --------------------------------------------------------------------------
# Server-side fold ops: add/extend resolve contention in one RPC
# --------------------------------------------------------------------------

def test_add_exact_under_eight_way_contention():
    """state.add from 8 concurrent cluster workers is a server-side fold:
    one RPC per delta, no CAS retry loop, and the count is *exact* —
    final value == sum of all deltas == final version."""
    rc.plan("cluster", workers=8)
    per_task = 25

    def body():
        from repro.core import state
        for _ in range(per_task):
            state.add("fold.add", 1)
        return True

    fs = [future(body) for _ in range(8)]
    assert value(gather(fs)) == [True] * 8
    assert state.get("fold.add") == 8 * per_task
    assert state.version("fold.add") == 8 * per_task
    rc.shutdown()


def test_extend_exact_under_eight_way_contention():
    """state.extend from 8 concurrent workers loses no element: the final
    list is a permutation of every appended item, exactly once each."""
    rc.plan("cluster", workers=8)
    per_task = 10

    def body(wid):
        from repro.core import state
        for i in range(per_task):
            state.extend("fold.list", [(wid, i)])
        return True

    fs = [future(lambda w=w: body(w)) for w in range(8)]
    assert value(gather(fs)) == [True] * 8
    got = state.get("fold.list")
    assert sorted(got) == sorted(
        (w, i) for w in range(8) for i in range(per_task))
    assert state.version("fold.list") == 8 * per_task
    rc.shutdown()


def test_add_default_and_return_value():
    """add returns the post-fold (value, version); default seeds the first
    fold; floats/negative deltas work (it's ``current + delta``, not a
    counter special case)."""
    assert state.add("acc.f", 2.5, default=10.0) == (12.5, 1)
    assert state.add("acc.f", -0.5) == (12.0, 2)
    n, ver = state.extend("acc.l", ["a", "b"])
    assert (n, ver) == (2, 1)
    n, ver = state.extend("acc.l", ["c"])
    assert (n, ver) == (3, 2)
    assert state.get("acc.l") == ["a", "b", "c"]


def test_wait_async_wakes_without_thread_per_waiter():
    """state.wait_async parks on the service watch list and resolves on
    the event loop — a put from another thread wakes the awaiting
    coroutine; a timeout raises StateTimeout."""
    import asyncio
    import threading

    async def main():
        fut = asyncio.ensure_future(
            state.wait_async("aw.k", 1, timeout=30))
        await asyncio.sleep(0.05)          # parked, not polling
        threading.Timer(0.05, lambda: state.put("aw.k", "go")).start()
        val, ver = await fut
        assert (val, ver) == ("go", 1)
        with pytest.raises(state.StateTimeout):
            await state.wait_async("aw.k", 99, timeout=0.1)

    asyncio.run(main())
