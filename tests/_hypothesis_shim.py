"""Import-or-shim for hypothesis, so tier-1 collection never breaks.

When hypothesis is installed (``pip install -r requirements-dev.txt``) the
real library is used and the full property sweeps run. Where it is not
available, a minimal deterministic fallback keeps the suite collecting and
running: ``@given`` draws a small number of pseudo-random samples from the
declared strategies with a fixed seed — a smoke-level sweep, not a
replacement for hypothesis's shrinking/coverage.

Usage in test modules::

    from _hypothesis_shim import given, settings, st

The shim caps examples at ``REPRO_SHIM_EXAMPLES`` (default 3) regardless of
``max_examples`` to keep the fallback suite fast; real hypothesis honours
the declared counts.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import os
    import random

    _SEED = 0xC0FFEE
    _CAP = int(os.environ.get("REPRO_SHIM_EXAMPLES", "3"))

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataObject:
        """Stand-in for hypothesis's ``data()`` draw handle."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.draw(self._rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def data():
            return _Strategy(lambda rng: _DataObject(rng))

    st = _St()

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def run(*args, **kwargs):
                declared = getattr(run, "_shim_max_examples",
                                   getattr(fn, "_shim_max_examples", 10))
                rng = random.Random(_SEED)
                for _ in range(min(declared, _CAP)):
                    drawn = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # deliberately NOT functools.wraps: copying __wrapped__ would
            # make pytest see the original signature and demand the strategy
            # parameters as fixtures.
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco
