"""Unit tests for the three Future constructs and creation semantics."""

import time
import warnings

import pytest

import repro.core as rc
from repro.core import Future, future, merge, resolved, value


def test_value_of_simple_future():
    f = future(lambda: 21 * 2)
    assert value(f) == 42
    assert resolved(f) is True


def test_snapshot_at_creation_globals():
    # paper: x <- 1; f <- future(slow_fcn(x)); x <- 2; value(f) uses x == 1
    global _snap_x
    _snap_x = 1
    f = future(lambda: _snap_x * 10)
    _snap_x = 2
    assert value(f) == 10


def test_snapshot_at_creation_closure():
    x = 1
    f = future(lambda: x * 10)
    x = 2  # noqa: F841 — rebinding must not affect the future
    assert value(f) == 10


def test_snapshot_copies_mutable_containers():
    xs = [1, 2, 3]
    f = future(lambda: sum(xs))
    xs.append(100)                      # mutation after creation is invisible
    assert value(f) == 6


def test_error_relayed_as_is_and_on_every_value():
    f = future(lambda: [0][3])
    with pytest.raises(IndexError):
        value(f)
    with pytest.raises(IndexError):     # errors re-raised every call
        value(f)


def test_stdout_and_warning_relay_order(capsys):
    def body():
        print("line-1")
        warnings.warn("warn-1")
        print("line-2")
        return 5

    f = future(body)
    with warnings.catch_warnings(record=True) as wlist:
        warnings.simplefilter("always")
        assert value(f) == 5
    out = capsys.readouterr().out
    # all stdout relayed (before conditions), in order
    assert out.index("line-1") < out.index("line-2")
    assert [str(w.message) for w in wlist] == ["warn-1"]
    # relayed only once
    value(f)
    assert "line-1" not in capsys.readouterr().out


def test_resolved_is_nonblocking():
    rc.plan("threads", workers=1)
    f = future(lambda: (time.sleep(0.3), "done")[1])
    t0 = time.time()
    r = resolved(f)
    assert time.time() - t0 < 0.2
    assert r is False
    assert value(f) == "done"


def test_creation_blocks_when_no_worker_free():
    rc.plan("threads", workers=1)
    future(lambda: time.sleep(0.25))
    t0 = time.time()
    f2 = future(lambda: "second")
    assert time.time() - t0 >= 0.2      # blocked for the busy worker
    assert value(f2) == "second"


def test_lazy_future_defers_until_touched():
    trace = []
    f = future(lambda: trace.append("ran") or 1, lazy=True)
    time.sleep(0.05)
    assert trace == []                  # not launched yet
    assert value(f) == 1


def test_merge_of_lazy_futures():
    fs = [future(lambda i=i: i * i, lazy=True) for i in range(5)]
    m1 = merge(fs[:3])
    m2 = merge(fs[3:])
    assert value(m1) == [0, 1, 4]
    assert value([m1, m2]) == [0, 1, 4, 9, 16]   # flattened like c(value..)


def test_merge_rejects_launched_futures():
    f = future(lambda: 1)
    with pytest.raises(rc.GlobalsError):
        merge([f])


def test_value_generic_containers():
    fs = {"a": future(lambda: 1), "b": [future(lambda: 2), 3]}
    assert value(fs) == {"a": 1, "b": [2, 3]}


def test_explicit_globals_argument():
    # paper: future(get("k"), globals = "k") — dynamic lookups need a hint
    def body():
        return globals()["k"]           # invisible to static analysis
    f = future(body, globals={"k": 42})
    assert value(f) == 42


def test_listenv_promise_container():
    env = rc.ListEnv()
    for i in range(4):
        env[i] = future(lambda i=i: i + 100)
    assert env.as_list() == [100, 101, 102, 103]


def test_cancel_unlaunched():
    rc.plan("threads", workers=1)
    blocker = future(lambda: time.sleep(0.3))
    f = future(lambda: "x", lazy=True)
    assert f.cancel() is False          # lazy/not submitted: nothing to cancel
    value(blocker)
