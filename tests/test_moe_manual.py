"""Manual (shard_map) expert parallelism == auto GSPMD path (subprocess
with fake devices). This is the correctness evidence for §Perf moe-2."""

import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")   # no TPU probing in the sandbox
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.models.moe import MoEDims, moe_init, moe_apply, moe_apply_manual

# capacity high enough that neither path drops tokens -> exact equality
dims = MoEDims(d_model=64, n_experts=8, top_k=2, d_expert=32, n_shared=2,
               capacity_factor=16.0)
key = jax.random.PRNGKey(0)
p = moe_init(key, dims, jnp.float32)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, 64))

y_auto, aux_auto = moe_apply(p, x, dims)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    y_man, aux_man = jax.jit(
        lambda p, x: moe_apply_manual(p, x, dims, mesh))(p, x)

np.testing.assert_allclose(np.asarray(y_man), np.asarray(y_auto),
                           rtol=2e-5, atol=2e-5)
# manual path computes the balance loss per data shard (the standard EP
# choice: balances per-device load); equal in expectation, not exactly
np.testing.assert_allclose(float(aux_man), float(aux_auto), atol=2e-3)
print("manual == auto OK")

# gradients flow through the manual path (psum + scatter transpose)
def loss(p):
    y, aux = moe_apply_manual(p, x, dims, mesh)
    return jnp.sum(y ** 2) + aux
with set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(p)
gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
assert np.isfinite(gn) and gn > 0
print("grad-ok", gn)

# padded experts (10 -> 12 over tp=4): pads must never contribute
dims_pad = MoEDims(d_model=64, n_experts=10, top_k=2, d_expert=32,
                   capacity_factor=16.0, n_experts_padded=12)
p2 = moe_init(jax.random.fold_in(key, 2), dims_pad, jnp.float32)
y2_auto, _ = moe_apply(p2, x, dims_pad)
with set_mesh(mesh):
    y2_man, _ = jax.jit(
        lambda p, x: moe_apply_manual(p, x, dims_pad, mesh))(p2, x)
np.testing.assert_allclose(np.asarray(y2_man), np.asarray(y2_auto),
                           rtol=2e-5, atol=2e-5)
print("padding OK")
"""


def test_manual_moe_matches_auto():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "manual == auto OK" in proc.stdout
    assert "padding OK" in proc.stdout
