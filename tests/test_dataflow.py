"""Worker-to-worker dataflow: remote results, locality-scheduled chains,
peer blob fetch.

The tentpole contract under test: a cluster task's large result stays on
the producing worker as a content-addressed blob (the driver holds a lazy
``RemoteValue``), continuation chains are scheduled onto the holder and
ship ~500 B of control frame instead of the value, and when locality is
impossible the bytes move worker-to-worker over the fetch/offer protocol —
with the driver as fallback. When holders die or evict, the driver
rebuilds lost blobs by re-executing their recorded lineage (see
test_lineage.py for the recovery battery) — dependent work gets the
bit-identical bytes back instead of a ``WorkerDiedError``.
Synchronization is always on observable driver / file-marker state — no
sleeps-as-synchronization.
"""

import os
import pickle
import socket
import time

import numpy as np
import pytest

import repro.core as rc
from _cluster_harness import HarnessLauncher
from repro.core import future, gather, stream, value
from repro.core.backends import transport
from repro.core.backends.blobstore import (DRIVER_STORE, RemoteValue,
                                           blob_digest)

pytestmark = pytest.mark.dataflow

#: big enough to cross RESULT_REF_THRESHOLD (64 KiB), small enough for fast
#: tests; the byte-reduction *bench* uses 8 MiB intermediates instead
_N = 1 << 17          # 1 MiB of float64

#: fast-heal knobs (same as test_faults) so fault cases run in seconds
_FAST = dict(heartbeat_interval=0.1, heartbeat_timeout=3.0,
             relaunch_backoff=0.05, relaunch_backoff_cap=0.2)


def _big(bias=0.0):
    """1 MiB payload; ``bias`` gives a test a digest no other test shares
    (DRIVER_STORE is process-global and content-addressed, so a digest
    pulled by an earlier test stays cached — loss/eviction tests need
    bytes nobody pulled before)."""
    return np.arange(_N, dtype=np.float64) + bias


def _remote_value_of(f):
    """The RemoteValue a resolved future's run carries (before value()
    materializes it)."""
    run = f._backend.collect(f._handle)
    assert isinstance(run.value, RemoteValue), run.value
    return run.value


def _holder_pids(backend, digest):
    wids = backend.locations(digest)
    with backend._pool_cv:
        return {w.meta.get("pid") for w in backend._all if w.wid in wids}


def _wait(pred, timeout=15.0, what="condition"):
    """Poll an observable driver-state predicate to a deadline."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.01)
    raise TimeoutError(f"{what} not reached within {timeout}s")


def _make_blocker(pidfile, release):
    """Chain body that parks its worker: publish my pid, hold the slot
    until the release marker appears, then compute. Built as a *local*
    function so it ships by value — a test-module global would pickle by
    reference to a module the workers cannot import."""
    def body(a, _p=pidfile, _r=release):
        import os as _os
        import time as _time
        with open(_p, "w") as fh:
            fh.write(str(_os.getpid()))
        while not _os.path.exists(_r):
            _time.sleep(0.005)
        return float(a[0])
    return body


# --------------------------------------------------------------------------
# Remote results + locality scheduling
# --------------------------------------------------------------------------

def test_large_result_stays_worker_resident_and_pulls_writable():
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    f = future(_big)
    rv = _remote_value_of(f)
    # the driver holds a digest + a location, not the bytes
    assert rv.nbytes >= _N * 8
    assert backend.locations(rv.digest)
    assert rv.digest not in DRIVER_STORE
    # value() is the explicit pull: correct bytes, writable copy
    v = f.value()
    assert isinstance(v, np.ndarray) and v.flags.writeable
    assert np.array_equal(v, _big())
    # pulled bytes are cached driver-side (holder death no longer loses them)
    assert rv.digest in DRIVER_STORE


def test_small_results_travel_inline():
    rc.plan("cluster", workers=2)
    f = future(lambda: np.arange(16, dtype=np.float64))
    run = f._backend.collect(f._handle)
    assert isinstance(run.value, np.ndarray)      # no RemoteValue detour
    assert np.array_equal(f.value(), np.arange(16, dtype=np.float64))


def test_chain_runs_on_holder_and_skips_the_driver_bytes():
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    f = future(_big)
    rv = _remote_value_of(f)
    holder_pids = _holder_pids(backend, rv.digest)
    transport.reset_wire_stats()
    g = f.then(lambda a: (os.getpid(), float(a.sum())))
    pid, total = g.value()
    sent = transport.wire_stats()["bytes_sent"]
    assert total == float(_big().sum())
    # locality: the continuation hop landed on the worker holding f's bytes
    assert pid in holder_pids
    # ~500 B control frame, not the ~1 MiB value, went through the driver
    assert sent < _N * 8 // 10, sent


def test_remote_value_refuses_direct_pickle():
    rc.plan("cluster", workers=1)
    f = future(_big)
    rv = _remote_value_of(f)
    with pytest.raises(TypeError, match="cannot be pickled directly"):
        pickle.dumps(rv)


def _driver_only_helper():
    return "never runs on a worker"


def test_undecodable_task_is_clean_error_not_worker_death():
    """A body referencing a test-module global function pickles it by
    reference to a module the worker cannot import: the decode failure is
    *that task's* error (relayed at value()), and the worker survives to
    serve the next future."""
    rc.plan("cluster", workers=1)
    backend = rc.active_backend()
    with pytest.raises(Exception, match="test_dataflow|_driver_only_helper"):
        future(lambda: _driver_only_helper()).value()
    # the worker did not die on the bad blob
    assert future(lambda: 41 + 1).value() == 42
    assert not backend._relaunch_log


def test_error_and_recover_mid_chain_with_remote_parent():
    rc.plan("cluster", workers=2)

    def boom(a):
        raise ValueError(f"boom:{int(a[0])}")

    f = future(_big)
    g = f.then(boom)
    with pytest.raises(ValueError, match="boom:0"):
        g.value()
    h = f.then(boom).recover(lambda exc: f"recovered:{exc}")
    assert h.value().startswith("recovered:")


def test_gather_pulls_cross_worker_results():
    rc.plan("cluster", workers=2)
    fs = [future(lambda k=k: np.full(_N, float(k))) for k in range(3)]
    got = value(gather(fs))
    for k, v in enumerate(got):
        assert np.array_equal(v, np.full(_N, float(k)))


def test_large_call_args_are_content_addressed_and_deduped():
    rc.plan("cluster", workers=1)
    big = np.full(_N, 3.0)
    assert future(lambda a: float(a.sum()), big).value() == float(big.sum())
    transport.reset_wire_stats()
    # same arg content again, same worker: the digest is known — no re-ship
    assert future(lambda a: float(a.sum()), big).value() == float(big.sum())
    assert transport.wire_stats()["bytes_sent"] < _N * 8 // 10


def test_remote_results_off_restores_inline_results():
    rc.plan("cluster", workers=2, remote_results=False)
    f = future(_big)
    run = f._backend.collect(f._handle)
    assert isinstance(run.value, np.ndarray)      # legacy wire shape
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_big().sum())


def test_worker_resident_and_gathered_values_are_bit_identical():
    """The dataflow path must be invisible in the numbers: the same seeded
    program yields byte-equal results with results worker-resident vs
    driver-gathered (remote_results=False)."""
    def prog():
        rc.set_session_seed(42)
        f = future(_big)
        g = f.then(lambda a: np.sqrt(a + 1.0))
        h = g.then(lambda a: a.tobytes())
        return h.value()

    rc.plan("cluster", workers=2, remote_results=True)
    via_workers = prog()
    rc.plan("cluster", workers=2, remote_results=False)
    via_driver = prog()
    assert via_workers == via_driver


def test_warm_pool_reattach_preserves_location_map():
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    f = future(_big)
    rv = _remote_value_of(f)
    assert backend.locations(rv.digest)
    rc.plan("sequential")                 # parks the cluster warm
    rc.plan("cluster", workers=2)         # re-attach, same spec/seed
    assert rc.active_backend() is backend
    assert backend.locations(rv.digest)   # map survived structurally
    assert np.array_equal(f.value(), _big())


def test_fused_stream_maps_match_cluster_and_sequential():
    ref = [float(np.sqrt(v * 2.0 + 1.0)) for v in range(12)]
    for name, kw in (("sequential", {}), ("cluster", {"workers": 2})):
        rc.plan(name, **kw)
        s = (stream(range(12))
             .map(lambda v: v * 2.0, chunk=3)
             .map(lambda v: float(np.sqrt(v + 1.0))))
        assert s.collect(ordered=True) == ref, name
        # adjacent maps fused into one pump: one future per chunk, total
        assert s.stats["dispatched"] == 4, (name, s.stats)


# --------------------------------------------------------------------------
# Peer fetch: protocol pin, busy holder, partition fallback, eviction
# --------------------------------------------------------------------------

def test_peer_server_protocol_pin():
    """Speak the fetch protocol to a worker's peer listener directly:
    a held digest comes back as a self-validating offer, a bogus digest
    as onak."""
    rc.plan("cluster", workers=1)
    backend = rc.active_backend()
    f = future(_big)
    rv = _remote_value_of(f)
    with backend._pool_cv:
        peers = [w.meta.get("peer") for w in backend._all]
    peer = next(p for p in peers if p)
    with socket.create_connection(tuple(peer), timeout=10) as s:
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        transport.send_frame(s, ("fetch", rv.digest))
        msg = transport.recv_frame(s)
        assert msg[0] == "offer" and msg[1] == rv.digest
        blob = bytes(msg[2])
        assert blob_digest(blob) == rv.digest     # content self-validates
        assert len(blob) == rv.nbytes
        transport.send_frame(s, ("fetch", b"\x00" * 16))
        msg = transport.recv_frame(s)
        assert msg[0] == "onak" and msg[1] == b"\x00" * 16


def test_peer_fetch_serves_chain_while_holder_is_busy(tmp_path):
    """Locality impossible (holder busy) -> the hop runs on the other
    worker, which fetches f's bytes worker-to-worker; the driver never
    routes the value."""
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    pidfile, release = str(tmp_path / "pid"), str(tmp_path / "go")
    f = future(_big)
    rv = _remote_value_of(f)
    holder_pids = _holder_pids(backend, rv.digest)
    # occupy the holder deterministically: this chain is locality-routed
    blocker = f.then(_make_blocker(pidfile, release))
    _wait(lambda: os.path.exists(pidfile), what="blocker pinned on holder")
    with open(pidfile) as fh:
        assert int(fh.read()) in holder_pids
    transport.reset_wire_stats()
    g = f.then(lambda a: (os.getpid(), float(a.sum())))
    pid, total = g.value()
    sent = transport.wire_stats()["bytes_sent"]
    open(release, "w").close()
    assert total == float(_big().sum())
    assert pid not in holder_pids            # ran on the non-holder
    # peer fetch moved the bytes worker-to-worker: driver sent ~no payload
    assert sent < _N * 8 // 10, sent
    assert blocker.value() == 0.0


def test_partitioned_peer_falls_back_to_driver(tmp_path):
    """Peers unreachable mid-fetch -> the worker degrades to ("need", d)
    and the driver serves the blob (pulling it off the busy holder's
    control socket) — correct value, no hang."""
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    pidfile, release = str(tmp_path / "pid"), str(tmp_path / "go")
    f = future(_big)
    rv = _remote_value_of(f)
    holder_pids = _holder_pids(backend, rv.digest)
    blocker = f.then(_make_blocker(pidfile, release))
    _wait(lambda: os.path.exists(pidfile), what="blocker pinned on holder")
    # partition the peer path: hints point at a dead port (connection
    # refused instantly — the simulated network partition)
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    dead_addr = probe.getsockname()
    probe.close()
    real_peer_addrs = backend._peer_addrs
    backend._peer_addrs = lambda digest, exclude=None: (
        ([dead_addr], None) if real_peer_addrs(digest, exclude)[0]
        else real_peer_addrs(digest, exclude))
    try:
        g = f.then(lambda a: (os.getpid(), float(a.sum())))
        pid, total = g.value()
    finally:
        backend._peer_addrs = real_peer_addrs
        open(release, "w").close()
    assert total == float(_big().sum())
    assert pid not in holder_pids
    assert blocker.value() == 0.0


def test_eviction_under_fetch_naks_then_driver_backfills():
    """A holder that evicted the digest answers onak — a requester with a
    driver-side copy gets backfilled, one without gets a clean
    ChannelError. Never stale bytes."""
    blob_bytes = int(_N * 8 * 1.5)       # room for ~one held result
    rc.plan("cluster", workers=2, blob_store_bytes=blob_bytes)
    backend = rc.active_backend()
    f = future(_big)
    rv = _remote_value_of(f)
    f.value()                             # driver now holds a copy
    assert rv.digest in DRIVER_STORE
    # locality-route a second big result onto the holder: its store is too
    # small for both, so f's blob is evicted there
    f2 = f.then(lambda a: a * 2.0)
    rv2 = _remote_value_of(f2)
    assert backend.locations(rv2.digest)
    # chain on f again: the worker's peer/need fetch meets the eviction;
    # the driver's cached copy backfills and the value is still correct
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_big().sum())


def test_evicted_everywhere_reconstructs_from_lineage():
    """Displace f's blob from its only holder: the pull finds no live
    copy anywhere, so the driver re-executes f's recorded producing task
    and the value comes back digest-identical."""
    blob_bytes = int(_N * 8 * 1.5)
    rc.plan("cluster", workers=1, blob_store_bytes=blob_bytes)
    backend = rc.active_backend()
    f = future(_big, 3.25)               # digest no earlier test pulled
    rv = _remote_value_of(f)
    # displace f's blob from its only holder (never pulled driver-side)
    f2 = f.then(lambda a: a + 1.0)
    _remote_value_of(f2)
    f2.value()                           # f2's blob now driver-side too
    # f's bytes may be gone everywhere: the pull rebuilds from lineage
    v = f.value()
    assert np.array_equal(v, _big(3.25))
    assert rv.digest in DRIVER_STORE     # rebuilt bytes are digest-exact
    assert backend is rc.active_backend()  # no restart happened under us


# --------------------------------------------------------------------------
# Holder death (harness launcher, hosts=2)
# --------------------------------------------------------------------------

@pytest.mark.launcher
def test_holder_death_recovers_dependent_chain_via_lineage():
    """SIGKILL the worker holding f's result before g dispatches: the
    driver re-executes f's recorded producing task, the chain resolves to
    the correct value (no WorkerDiedError escapes), and the recovery is
    visible in ``recovery_stats()``."""
    h = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=h, **_FAST)
    backend = rc.active_backend()
    f = future(_big, 5.5)                # digest no earlier test pulled
    rv = _remote_value_of(f)
    pid = next(iter(_holder_pids(backend, rv.digest)))
    wp = h.by_pid(pid)
    assert wp is not None
    h.kill(wp)
    # deterministic gate: the driver has processed the death once the
    # location map no longer lists any holder for the digest
    _wait(lambda: not backend.locations(rv.digest), what="death detected")
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_big(5.5).sum())
    assert f.value() is not None and np.array_equal(f.value(), _big(5.5))
    assert backend.recovery_stats()["reconstructions"] >= 1
    # self-heal: the replacement joins and fresh chains work end to end
    h.wait_launches(3)
    f2 = future(_big)
    assert f2.then(lambda a: float(a.sum())).value() == float(_big().sum())


@pytest.mark.launcher
def test_pulled_result_survives_holder_death():
    """A result pulled to the driver before its holder dies stays
    available: DRIVER_STORE is a location too."""
    h = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=h, **_FAST)
    backend = rc.active_backend()
    f = future(_big, 6.5)                # digest no earlier test pulled
    rv = _remote_value_of(f)
    v1 = f.value()                        # pull + cache driver-side
    pid = next(iter(_holder_pids(backend, rv.digest)))
    h.kill(h.by_pid(pid))
    _wait(lambda: not backend.locations(rv.digest), what="death detected")
    # chain after the death: driver-fallback serves the cached bytes
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_big(6.5).sum())
    assert np.array_equal(v1, _big(6.5))


# --------------------------------------------------------------------------
# Driver-side GC of worker-resident blobs
# --------------------------------------------------------------------------

def test_remote_value_gc_releases_worker_blobs():
    """Dropping the last driver-side reference to a RemoteValue evicts the
    blob from its holders — worker memory is reclaimed without shutdown.
    The release is refcounted finalizers feeding the select loop, which
    sends ``("evict", digest)`` to every live holder."""
    import gc
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    f = future(_big, 41.25)               # digest unique to this test
    rv = _remote_value_of(f)
    digest = rv.digest
    assert backend.locations(digest)
    del f, rv
    gc.collect()
    _wait(lambda: not backend.locations(digest), what="GC eviction")


def test_gc_spares_shared_digest_until_last_reference_dies():
    """Two futures producing identical content share one digest; dropping
    one must NOT evict — the refcount holds until both are gone."""
    import gc
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    f1 = future(_big, 42.75)
    f2 = future(_big, 42.75)              # same content, same digest
    rv1, rv2 = _remote_value_of(f1), _remote_value_of(f2)
    assert rv1.digest == rv2.digest
    digest = rv1.digest
    del f1, rv1
    gc.collect()
    time.sleep(0.3)                       # give a wrong eviction time to land
    assert backend.locations(digest)      # second reference still pins it
    assert f2.then(lambda a: float(a.sum())).value() == float(_big(42.75).sum())
    del f2, rv2
    gc.collect()
    _wait(lambda: not backend.locations(digest), what="GC eviction")


def test_chain_on_gc_candidate_still_resolves():
    """An in-flight continuation anchors its parent's RemoteValue: GC of
    the user's handle mid-chain must not evict bytes the chain needs."""
    import gc
    rc.plan("cluster", workers=2)
    f = future(_big, 43.5)
    g = f.then(lambda a: float(a.sum()))  # chain holds the anchor
    del f
    gc.collect()
    assert g.value() == float(_big(43.5).sum())
