"""Cooperative (asyncio) frontend tests: ``await f``, ``async for`` over
completions, the event-loop backend, and the completion-kernel bugfixes
that shipped with it (thread reuse, waiter tombstones, resolve timeout,
jax_async callback race, abandonment cleanup).

pytest-asyncio is deliberately not a dependency: every test is a sync
function driving its coroutine with ``asyncio.run`` — what a library user
without the plugin would write.
"""

import asyncio
import gc
import threading
import time
import weakref

import pytest

import repro.core as rc
from repro.core import (FutureCancelledError, Waiter, as_completed,
                        as_completed_async, future, resolve, stream, value)
from repro.core.planning import active_backend

pytestmark = pytest.mark.asyncio


@pytest.fixture
def aio_backend():
    rc.plan("asyncio")
    yield active_backend()
    rc.shutdown()


@pytest.fixture
def threads_backend():
    rc.plan("threads", workers=4)
    yield active_backend()
    rc.shutdown()


# --------------------------------------------------------------------------
# await f — works on every backend, not just plan("asyncio")
# --------------------------------------------------------------------------

def test_await_returns_value_on_thread_backend(threads_backend):
    async def main():
        f = future(lambda: time.sleep(0.05) or 21)
        return await f
    assert asyncio.run(main()) == 21


def test_await_reraises_error_every_await(threads_backend):
    async def main():
        f = future(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            await f
        with pytest.raises(ZeroDivisionError):
            await f                      # errors re-raise on every await
    asyncio.run(main())


def test_await_relays_stdout_and_value(aio_backend, capsys):
    async def body():
        print("before-sleep")
        await asyncio.sleep(0.01)
        print("after-sleep")
        return 7

    async def main():
        return await future(body)

    assert asyncio.run(main()) == 7
    out = capsys.readouterr().out
    assert out.index("before-sleep") < out.index("after-sleep")


def test_await_already_resolved_future(threads_backend):
    f = future(lambda: 5)
    assert value(f) == 5

    async def main():
        return await f                   # fast path: no callback registration
    assert asyncio.run(main()) == 5


# --------------------------------------------------------------------------
# plan("asyncio"): async bodies share one loop, no thread parked per future
# --------------------------------------------------------------------------

def test_async_bodies_run_concurrently(aio_backend):
    async def body(i):
        await asyncio.sleep(0.2)
        return i

    async def main():
        fs = [future(body, i) for i in range(20)]
        return [await f for f in fs]

    t0 = time.monotonic()
    assert asyncio.run(main()) == list(range(20))
    # 20 x 0.2s of sleep overlapped on one loop: far below the 4s serial wall
    assert time.monotonic() - t0 < 2.0


def test_no_thread_per_inflight_future(aio_backend):
    async def body():
        await asyncio.sleep(0.3)
        return 1

    async def main():
        fs = [future(body) for _ in range(500)]
        peak = threading.active_count()
        vals = [await f for f in fs]
        return peak, vals

    peak, vals = asyncio.run(main())
    assert vals == [1] * 500
    # 500 in-flight futures but only the backend loop thread (plus pytest's
    # own few) — nothing remotely like a thread per future
    assert peak < 20


def test_sync_bodies_work_on_asyncio_backend(aio_backend):
    fs = [future(lambda i=i: i * i) for i in range(8)]
    assert value(fs) == [i * i for i in range(8)]


def test_cancel_runs_async_finally_and_raises(aio_backend):
    cleaned = threading.Event()

    async def body():
        try:
            await asyncio.sleep(30)
        finally:
            cleaned.set()

    f = future(body)
    time.sleep(0.1)                      # let the body reach its await
    f.cancel()
    with pytest.raises(FutureCancelledError):
        value(f)
    assert cleaned.is_set()              # cancellation was thrown *into* the body


def test_blocking_value_on_loop_thread_raises(aio_backend):
    async def slow():
        await asyncio.sleep(30)

    f_slow = future(slow)

    def bad_body():
        return f_slow.value()            # blocking wait on the loop thread

    f = future(bad_body)
    with pytest.raises(RuntimeError, match="deadlock"):
        value(f)
    f_slow.cancel()


# --------------------------------------------------------------------------
# as_completed_async / AsyncWaiter
# --------------------------------------------------------------------------

def test_as_completed_async_yields_in_completion_order(threads_backend):
    async def main():
        slow = future(lambda: time.sleep(0.3) or "slow")
        fast = future(lambda: "fast")
        order = []
        async for f in as_completed_async([slow, fast]):
            order.append(await f)
        return order
    assert asyncio.run(main()) == ["fast", "slow"]


def test_as_completed_async_timeout(threads_backend):
    async def main():
        f = future(lambda: time.sleep(5))
        with pytest.raises(TimeoutError):
            async for _ in as_completed_async([f], timeout=0.1):
                pass
        f.cancel()
    asyncio.run(main())


def test_as_completed_async_on_asyncio_backend(aio_backend):
    async def body(i):
        await asyncio.sleep(0.01 * (5 - i))
        return i

    async def main():
        fs = [future(body, i) for i in range(5)]
        return [await f async for f in as_completed_async(fs)]

    # later-indexed futures sleep less, so completion order is reversed
    assert asyncio.run(main()) == [4, 3, 2, 1, 0]


# --------------------------------------------------------------------------
# stream async terminals
# --------------------------------------------------------------------------

def test_stream_collect_async(aio_backend):
    async def main():
        return await (stream(iter(range(10)))
                      .filter(lambda v: v % 2 == 0)
                      .map(lambda v: v * 10)
                      .collect_async())
    assert asyncio.run(main()) == [0, 20, 40, 60, 80]


def test_stream_async_map_fn(aio_backend):
    async def double(v):
        await asyncio.sleep(0.01)
        return v * 2

    async def main():
        return await stream(iter(range(6))).map(double, chunk=2).collect_async()
    assert asyncio.run(main()) == [0, 2, 4, 6, 8, 10]


def test_stream_as_completed_async_unordered(aio_backend):
    async def jitter(v):
        await asyncio.sleep(0.005 * (v % 3))
        return v

    async def main():
        got = []
        async for v in stream(iter(range(12))).map(jitter).as_completed_async():
            got.append(v)
        return got

    assert sorted(asyncio.run(main())) == list(range(12))


def test_stream_async_terminal_on_thread_backend(threads_backend):
    async def main():
        return await stream(iter(range(8))).map(lambda v: v + 100).collect_async()
    assert asyncio.run(main()) == list(range(100, 108))


def test_stream_async_abandonment_releases_slots(aio_backend):
    cap = active_backend().workers

    async def slow(v):
        await asyncio.sleep(0.5)
        return v

    async def main():
        agen = stream(iter(range(40))).map(slow).as_completed_async()
        async for _ in agen:
            break                        # abandon with ~39 futures in flight
        await agen.aclose()
        deadline = time.monotonic() + 5
        be = active_backend()
        while be.free_slots() != cap and time.monotonic() < deadline:
            await asyncio.sleep(0.02)
        return be.free_slots()

    assert asyncio.run(main()) == cap    # in-flight tail was cancelled


# --------------------------------------------------------------------------
# S5: generator abandonment must not leak callbacks or pin futures
# --------------------------------------------------------------------------

def test_abandoned_as_completed_does_not_pin_futures(threads_backend):
    fs = [future(lambda i=i: time.sleep(0.02) or i) for i in range(6)]
    refs = [weakref.ref(f) for f in fs]
    gen = as_completed(fs)
    next(gen)                            # consume one, abandon the rest
    gen.close()
    resolve(fs)                          # let every body finish first
    del gen, fs
    gc.collect()
    assert all(r() is None for r in refs)


def test_abandoned_as_completed_async_does_not_pin_futures(threads_backend):
    refs = []

    async def main():
        fs = [future(lambda i=i: time.sleep(0.02) or i) for i in range(6)]
        refs.extend(weakref.ref(f) for f in fs)
        agen = as_completed_async(fs)
        await agen.__anext__()
        await agen.aclose()
        resolve(fs)

    asyncio.run(main())
    gc.collect()
    assert all(r() is None for r in refs)


# --------------------------------------------------------------------------
# S1: thread backend reuses idle workers
# --------------------------------------------------------------------------

def test_thread_backend_reuses_idle_worker(threads_backend):
    be = threads_backend
    idents = []
    for _ in range(5):
        idents.append(value(future(threading.get_ident)))
        # wait until the worker has parked back on the dispatch queue, so
        # the next submit must claim it instead of spawning
        deadline = time.monotonic() + 2
        while be._idle < 1 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert be._idle >= 1
    assert len(set(idents)) == 1


def test_thread_backend_concurrency_unchanged(threads_backend):
    t0 = time.monotonic()
    fs = [future(lambda: time.sleep(0.2) or 1) for _ in range(4)]
    assert value(fs) == [1] * 4
    assert time.monotonic() - t0 < 0.8   # 4 bodies overlapped on 4 workers


# --------------------------------------------------------------------------
# S2: Waiter.add() after delivery is a no-op (tombstones)
# --------------------------------------------------------------------------

def test_waiter_readd_after_delivery_is_noop(threads_backend):
    f = future(lambda: 3)
    w = Waiter([f])
    got = w.wait(timeout=5)
    assert got == [f]
    w.add(f)                             # must not re-deliver
    assert w.wait(timeout=0.2) == []


def test_waiter_tombstones_do_not_pin(threads_backend):
    f = future(lambda: 3)
    ref = weakref.ref(f)
    w = Waiter([f])
    assert w.wait(timeout=5) == [f]
    del f
    gc.collect()
    assert ref() is None                 # tombstone is weak
    assert len(w) == 0


# --------------------------------------------------------------------------
# S3: resolve(timeout=) now raises instead of returning indistinguishably
# --------------------------------------------------------------------------

def test_resolve_timeout_raises_and_future_stays_valid(threads_backend):
    f = future(lambda: time.sleep(0.3) or 9)
    with pytest.raises(TimeoutError):
        resolve([f], timeout=0.05)
    assert value(f) == 9                 # still collectable afterwards


# --------------------------------------------------------------------------
# S4: jax_async add_done_callback under registration/completion races
# --------------------------------------------------------------------------

def test_jax_async_callback_exactly_once_under_races():
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    rc.plan("jax_async")
    try:
        be = active_backend()
        for _ in range(30):
            f = future(lambda: jnp.arange(16).sum())
            fired = []
            lock = threading.Lock()

            def register(k, _f=f, _fired=fired, _lock=lock):
                def cb(_h, _k=k):
                    with _lock:
                        _fired.append(_k)
                be.add_done_callback(_f._handle, cb)

            ts = [threading.Thread(target=register, args=(k,))
                  for k in range(4)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            deadline = time.monotonic() + 5
            while time.monotonic() < deadline:
                with lock:
                    if len(fired) >= 4:
                        break
                time.sleep(0.001)
            with lock:
                assert sorted(fired) == [0, 1, 2, 3]   # each exactly once
            assert int(value(f)) == 120
    finally:
        rc.shutdown()
