"""Multi-tenant secure serving tier (`repro.core.serving`).

Covers the three security/tenancy layers end to end, in one process (the
server's inner cluster still launches real worker processes):

* transport security — wrong token, plaintext-to-TLS and unauthenticated
  raw connections are all rejected *before any frame decode*, with a
  clear ChannelError on the client side and never a hang;
* the long-lived driver server — concurrent sessions over one warm
  cluster, each with the full future/state API, session TTL expiry;
* per-tenant policy — fair-share caps actually bound a tenant's worker
  occupancy, state is namespaced per tenant, and wire/recovery stats are
  attributed to the right tenant.
"""

import itertools
import threading
import time

import pytest

import repro.core as rc
from _cluster_harness import ephemeral_tls
from repro.core import future, gather, state, value
from repro.core.backends.base import TaskSpec
from repro.core.errors import ChannelError
from repro.core.globals_capture import dumps_robust, ship_function
from repro.core.serving import ServingClientBackend, serve

pytestmark = pytest.mark.serving

_ids = itertools.count(1)


def _task(fn):
    """Hand-build the shipped TaskSpec future.py would produce, for tests
    driving ServingClientBackend directly (two sessions in one process —
    plan() is global, so the second tenant can't come from plan())."""
    sources: dict = {}
    shipped = dumps_robust(
        {"fn": ship_function(fn, {}, (), ref_sink=sources),
         "args": (), "kwargs": {}, "capture_stdout": True,
         "capture_conditions": True, "seed_declared": False},
        ref_sink=sources)
    return TaskSpec(task_id=next(_ids), fn=None, shipped=shipped,
                    payload_sources=sources)


def _value(client, handle):
    run = client.collect(handle)
    if run.error is not None:
        raise run.error
    return run.value


# --------------------------------------------------------------------------
# Transport security: rejected before any frame decode, never a hang
# --------------------------------------------------------------------------

def test_wrong_token_rejected_fast_and_server_survives():
    with serve({"workers": 1}, tokens={"alice": "s1"}) as srv:
        host, port = srv.address
        t0 = time.monotonic()
        with pytest.raises(ChannelError):
            ServingClientBackend(addr=(host, port), token="WRONG")
        assert time.monotonic() - t0 < 11.0
        # the listener shrugged it off: a good credential still works
        c = ServingClientBackend(addr=(host, port), token="s1")
        assert _value(c, c.submit(_task(lambda: 7))) == 7
        c.shutdown()


def test_plaintext_dial_to_tls_listener_rejected():
    with serve({"workers": 1}, tokens={"a": "s"},
               tls=ephemeral_tls()) as srv:
        host, port = srv.address
        t0 = time.monotonic()
        with pytest.raises(ChannelError):
            ServingClientBackend(addr=(host, port), token="s")  # no TLS
        assert time.monotonic() - t0 < 11.0
        ca = srv.tls.certfile
        c = ServingClientBackend(addr=(host, port), token="s", tls_ca=ca)
        assert _value(c, c.submit(_task(lambda: 8))) == 8
        c.shutdown()


def test_unauthenticated_raw_socket_cannot_submit():
    """A raw connection that skips the handshake and speaks protocol
    frames directly gets disconnected without ever reaching the frame
    decoder — it can neither submit tasks nor pull state/blobs."""
    import socket as socket_mod

    from repro.core.backends.transport import recv_frame, send_frame
    with serve({"workers": 1}, tokens={"a": "s"}) as srv:
        raw = socket_mod.create_connection(srv.address, timeout=5)
        raw.settimeout(10.0)
        with pytest.raises((ChannelError, EOFError, OSError)):
            # first bytes are not the AUTH magic -> listener hangs up
            send_frame(raw, ("sub", 1, b"evil", [], {}, {}))
            recv_frame(raw)
        raw.close()


def test_cluster_listener_rejects_tokenless_worker_dial():
    """The inner cluster's own worker listener is behind the same
    preamble: a tokenless dial is refused, so an attacker can't skip the
    serving tier and register as a 'worker' to receive task pickles."""
    from repro.core.backends.cluster_worker import run_worker
    with serve({"workers": 1, "token": "wsecret"},
               tokens={"a": "s"}) as srv:
        caddr = srv.inner.address
        with pytest.raises((ChannelError, EOFError, OSError)):
            run_worker(caddr[0], caddr[1], token="BAD")


# --------------------------------------------------------------------------
# The long-lived server: sessions, full API, TTL
# --------------------------------------------------------------------------

def test_plan_serving_full_future_and_state_api():
    with serve({"workers": 2}, tokens={"alice": "s1"}) as srv:
        host, port = srv.address
        rc.plan("serving", addr=f"{host}:{port}", token="s1")
        # futures, gather, closures with captured payloads
        xs = [future(lambda i=i: i * i) for i in range(6)]
        assert value(gather(xs)) == [i * i for i in range(6)]
        # error relay: evaluation errors come back as themselves
        with pytest.raises(ZeroDivisionError):
            value(future(lambda: 1 // 0))
        # state: driver-side calls and task-body calls hit the same
        # tenant-scoped namespace on the server
        state.put("cfg", {"lr": 0.1})
        n, _ = state.add("steps", 3)
        assert n == 3

        def body():
            from repro.core import state as st
            st.add("steps", 1)
            return st.get("cfg")["lr"]

        assert value(future(body)) == 0.1
        assert state.get("steps") == 4
        be = rc.planning.active_backend()
        stats = be.session_stats()
        assert stats["tenant"] == "alice"
        assert stats["tenant_stats"]["completed"] >= 8
        rc.plan("sequential")
        rc.shutdown()


def test_session_ttl_expiry_is_a_clean_error_not_a_hang():
    with serve({"workers": 1}, tokens={"t": "x"}, session_ttl=0.8) as srv:
        c = ServingClientBackend(addr=srv.address, token="x")
        assert _value(c, c.submit(_task(lambda: 1))) == 1
        time.sleep(1.4)
        t0 = time.monotonic()
        with pytest.raises(ChannelError, match="expired"):
            c.free_slots()
        with pytest.raises(ChannelError, match="expired"):
            c.submit(_task(lambda: 2))
        # the state API's error contract is StateError; the expired-session
        # ChannelError rides inside it, still instant and still clear
        with pytest.raises((ChannelError, state.StateError), match="expired"):
            c._state.get("anything")
        assert time.monotonic() - t0 < 5.0
        c.shutdown()


# --------------------------------------------------------------------------
# Tenancy: isolation, fair-share caps, attribution
# --------------------------------------------------------------------------

def test_two_tenant_sessions_state_isolation_and_attribution():
    with serve({"workers": 2},
               tokens={"alice": "s1", "bob": "s2"},
               tenants={"alice": {"weight": 3.0},
                        "bob": {"weight": 1.0}}) as srv:
        a = ServingClientBackend(addr=srv.address, token="s1")
        b = ServingClientBackend(addr=srv.address, token="s2")
        assert (a.tenant, b.tenant) == ("alice", "bob")
        ha = [a.submit(_task(lambda i=i: ("a", i))) for i in range(5)]
        hb = [b.submit(_task(lambda i=i: ("b", i))) for i in range(3)]
        assert [_value(a, h) for h in ha] == [("a", i) for i in range(5)]
        assert [_value(b, h) for h in hb] == [("b", i) for i in range(3)]
        # same key, different namespaces
        a._state.put("k", "alice-data")
        b._state.put("k", "bob-data")
        assert a._state.get("k") == "alice-data"
        assert b._state.get("k") == "bob-data"
        # attribution: each session sees its own tenant's counters
        sa, sb = a.session_stats(), b.session_stats()
        assert sa["tenant_stats"]["completed"] == 5
        assert sb["tenant_stats"]["completed"] == 3
        assert sa["tenant_stats"]["bytes_sent"] > 0
        assert "by_tenant" in sa["recovery"]
        a.shutdown()
        b.shutdown()


def test_max_in_flight_cap_keeps_a_worker_free_for_the_other_tenant():
    """Tenant ``hog`` is capped at one in-flight task; its burst of slow
    tasks serializes on one worker while ``small``'s task grabs the other
    worker immediately — a flooding tenant cannot occupy the fleet."""
    with serve({"workers": 2},
               tokens={"hog": "h", "small": "s"},
               tenants={"hog": {"max_in_flight": 1},
                        "small": {}}) as srv:
        hog = ServingClientBackend(addr=srv.address, token="h")
        small = ServingClientBackend(addr=srv.address, token="s")
        hh = [hog.submit(_task(
                  lambda: __import__("time").sleep(0.4) or "slow"))
              for _ in range(4)]
        t0 = time.monotonic()
        assert _value(small, small.submit(_task(lambda: "quick"))) == "quick"
        quick_latency = time.monotonic() - t0
        assert [_value(hog, h) for h in hh] == ["slow"] * 4
        hog_wall = time.monotonic() - t0
        # 4 serialized 0.4s sleeps ~1.6s; the capped tenant must not have
        # parallelized, and the small tenant must not have queued behind it
        assert quick_latency < 1.0, quick_latency
        assert hog_wall > 1.2, hog_wall
        assert hog.session_stats()["tenant_stats"]["completed"] == 4
        hog.shutdown()
        small.shutdown()


# --------------------------------------------------------------------------
# Warm-pool security regression (satellite): credentials are key material
# --------------------------------------------------------------------------

def test_warm_pool_key_handles_dict_kwargs_and_credentials(monkeypatch):
    from repro.core import planning
    # dict-valued kwargs (tenants=...) must be poolable, not a TypeError
    rc.plan("cluster", workers=1, tenants={"a": {"weight": 2.0}})
    b1 = planning.active_backend()
    assert value(future(lambda: 1)) == 1
    rc.plan("threads")
    rc.plan("cluster", workers=1, tenants={"a": {"weight": 2.0}})
    assert planning.active_backend() is b1      # same spec -> reattach
    # a credential change is an identity change: same kwargs, new token
    # must NOT reattach to the unsecured warm pool
    rc.plan("threads")
    monkeypatch.setenv("REPRO_CLUSTER_TOKEN", "rotated-secret")
    rc.plan("cluster", workers=1, tenants={"a": {"weight": 2.0}})
    b2 = planning.active_backend()
    assert b2 is not b1
    assert value(future(lambda: 2)) == 2
    rc.shutdown()


def test_warm_pool_key_hashes_tls_config_material():
    from repro.core import planning
    tls = ephemeral_tls()
    k1 = planning._backend_key(
        planning.spec("cluster", workers=1, token="t", tls=tls),
        (planning.spec("cluster", workers=1, token="t", tls=tls),))
    hash(k1)                                    # must be hashable
    k2 = planning._backend_key(
        planning.spec("cluster", workers=1, token="other", tls=tls),
        (planning.spec("cluster", workers=1, token="other", tls=tls),))
    assert k1 != k2                             # token is key material
    # and the raw token never appears in the key (it's hashed)
    assert "other" not in repr(k2)


def test_weighted_fair_share_interleaves_3_to_1():
    """Start-time fair queuing, end to end through the serving tier: with
    one worker and both queues backlogged, the weight-3 tenant gets
    exactly 3 of every 4 dispatches — not FIFO by arrival, and no
    starvation of the light tenant while heavy's queue is deep."""
    with serve({"workers": 1},
               tokens={"heavy": "h", "light": "l"},
               tenants={"heavy": {"weight": 3.0},
                        "light": {"weight": 1.0}}) as srv:
        heavy = ServingClientBackend(addr=srv.address, token="h")
        light = ServingClientBackend(addr=srv.address, token="l")
        order: list = []                  # list.append is atomic
        handles = []
        for client, name, n in ((heavy, "heavy", 12), (light, "light", 12)):
            for i in range(n):
                h = client.submit(_task(
                    lambda: __import__("time").sleep(0.02) or True))
                client.add_done_callback(
                    h, lambda _h, n=name: order.append(n))
                handles.append((client, h))
        for client, h in handles:
            client.collect(h)
        window = order[:12]
        share = sum(1 for n in window if n == "heavy") / len(window)
        # ideal is 0.75; one worker + frozen enqueue tags make the
        # schedule deterministic up to the first dispatch race
        assert 0.6 <= share <= 0.9, (share, order)
        assert "light" in window, "light tenant starved"
        heavy.shutdown()
        light.shutdown()
