"""Lineage-based reconstruction + proactive replication (robustness PR).

The contract under test: a worker-resident result whose every copy is
gone — holder SIGKILLed, evicted under store pressure, or raced away —
is transparently **re-produced by re-executing its recorded producing
task** (recursively for missing parents, capped by ``lineage_max_depth``
/ ``lineage_max_attempts``), and the rebuilt bytes are digest-identical
because the shipped task blob froze the RNG stream key and every
content-addressed input ref at creation. ``min_replicas=2`` layers
proactive replication on the same machinery so a single holder death
needs *zero* re-executions. Synchronization is always on observable
driver / file-marker state — no sleeps-as-synchronization.
"""

import os
import time

import numpy as np
import pytest

import repro.core as rc
from _cluster_harness import HarnessLauncher
from repro.core import future
from repro.core.backends.blobstore import DRIVER_STORE, RemoteValue

pytestmark = pytest.mark.lineage

#: crosses RESULT_REF_THRESHOLD (64 KiB); fast for the non-acceptance cases
_N = 1 << 17          # 1 MiB of float64

#: the acceptance scenario sizes the intermediate at 8 MiB
_N8 = 1 << 20         # 8 MiB of float64

#: fast-heal knobs (same as test_faults / test_dataflow)
_FAST = dict(heartbeat_interval=0.1, heartbeat_timeout=3.0,
             relaunch_backoff=0.05, relaunch_backoff_cap=0.2)


def _big(bias=0.0):
    """1 MiB payload with a test-unique digest (DRIVER_STORE is
    process-global: loss tests need bytes no earlier test pulled)."""
    return np.arange(_N, dtype=np.float64) + bias


def _big8(bias=0.0):
    return np.arange(_N8, dtype=np.float64) + bias


def _remote_value_of(f):
    run = f._backend.collect(f._handle)
    assert isinstance(run.value, RemoteValue), run.value
    return run.value


def _holder_pids(backend, digest):
    wids = backend.locations(digest)
    with backend._pool_cv:
        return {w.meta.get("pid") for w in backend._all if w.wid in wids}


def _wait(pred, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.01)
    raise TimeoutError(f"{what} not reached within {timeout}s")


def _make_once_blocker(pidfile):
    """Chain body whose *first* execution publishes its pid and parks
    forever (until the harness SIGKILLs the worker); the re-execution
    after recovery sees the marker and computes. Local function so it
    ships by value."""
    def body(a, _p=pidfile):
        import os as _os
        import time as _time
        if not _os.path.exists(_p):
            with open(_p, "w") as fh:
                fh.write(str(_os.getpid()))
            while True:
                _time.sleep(0.005)
        return float(a.sum())
    return body


def _make_parker(pidfile, release):
    """Chain body that parks its worker until the release marker."""
    def body(a, _p=pidfile, _r=release):
        import os as _os
        import time as _time
        with open(_p, "w") as fh:
            fh.write(str(_os.getpid()))
        while not _os.path.exists(_r):
            _time.sleep(0.005)
        return float(a[0])
    return body


# --------------------------------------------------------------------------
# Acceptance: sole holder of an 8 MiB intermediate dies mid-chain
# --------------------------------------------------------------------------

@pytest.mark.launcher
def test_sole_holder_sigkill_midchain_rebuilds_bit_identical(tmp_path):
    """SIGKILL the sole holder of an 8 MiB intermediate while the
    dependent hop runs on it: the hop retry re-submits, the submit
    preflight re-executes f's recorded lineage on the survivor, and the
    chain resolves to the correct value under the *original* digest — no
    WorkerDiedError reaches user code."""
    h = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=h, **_FAST)
    backend = rc.active_backend()
    f = future(_big8, 17.5)
    digest = _remote_value_of(f).digest
    assert digest not in DRIVER_STORE     # sole copy is worker-resident
    pidfile = str(tmp_path / "holder.pid")
    watcher = h.kill_on_pidfile(pidfile)
    # locality routes the hop onto the holder — the kill is guaranteed to
    # land mid-task on the worker holding the intermediate
    g = f.then(_make_once_blocker(pidfile))
    assert g.value() == float(_big8(17.5).sum())
    watcher.join(30.0)
    assert watcher.killed is not None     # the kill really landed
    assert backend.recovery_stats()["reconstructions"] >= 1
    # bit-identical replay: pulling by the ORIGINAL digest succeeds and
    # decodes to the original value
    assert np.array_equal(f.value(), _big8(17.5))
    assert digest in DRIVER_STORE


# --------------------------------------------------------------------------
# Acceptance: min_replicas=2 — same death, zero re-executions
# --------------------------------------------------------------------------

@pytest.mark.launcher
def test_min_replicas_survives_holder_death_with_zero_reexecutions():
    h = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=h, min_replicas=2, **_FAST)
    backend = rc.active_backend()
    f = future(_big8, 23.5)
    digest = _remote_value_of(f).digest
    _wait(lambda: len(backend.locations(digest)) >= 2,
          what="proactive replica registered")
    assert backend.recovery_stats()["replications"] >= 1
    pid = next(iter(_holder_pids(backend, digest)))
    with backend._pool_cv:
        dead_wid = next(w.wid for w in backend._all
                        if w.meta.get("pid") == pid)
    h.kill(h.by_pid(pid))
    _wait(lambda: dead_wid not in backend.locations(digest)
          and backend.locations(digest),
          what="death pruned; surviving replica still registered")
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_big8(23.5).sum())
    assert backend.recovery_stats()["reconstructions"] == 0


# --------------------------------------------------------------------------
# Caps surface a clear LineageExhaustedError
# --------------------------------------------------------------------------

def test_reexecution_budget_exhausted_surfaces_clear_error():
    """lineage_max_attempts=0 turns every rebuild into the budget error:
    displace the sole copy under store pressure, then pull."""
    blob_bytes = int(_N * 8 * 1.5)
    rc.plan("cluster", workers=1, blob_store_bytes=blob_bytes,
            lineage_max_attempts=0)
    f = future(_big, 29.25)
    _remote_value_of(f)
    f2 = f.then(lambda a: a + 1.0)        # displaces f's blob on the holder
    f2.value()
    with pytest.raises(rc.LineageExhaustedError, match="budget"):
        f.value()


def test_depth_cap_raises_lineage_exhausted():
    rc.plan("cluster", workers=1)
    backend = rc.active_backend()
    with pytest.raises(rc.LineageExhaustedError, match="depth cap"):
        backend._reconstruct(b"\x00" * 16,
                             _depth=backend._lineage_max_depth + 1)


def test_lost_digest_without_lineage_is_diagnosable():
    """Bytes the driver never saw produced (no recorded task) fail with
    the no-lineage message, not a hang."""
    rc.plan("cluster", workers=1)
    backend = rc.active_backend()
    with pytest.raises(rc.LineageExhaustedError,
                       match="no producing task is recorded"):
        backend._reconstruct(b"\x01" * 16)


# --------------------------------------------------------------------------
# Bounded bookkeeping: GC hook + LRU cap
# --------------------------------------------------------------------------

def test_gc_drops_lineage_record():
    """Evicting a digest via RemoteValue GC also drops its lineage: the
    registry cannot outgrow the set of live results."""
    import gc
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    f = future(_big, 31.75)
    rv = _remote_value_of(f)
    digest = rv.digest
    with backend._lineage_lock:
        assert digest in backend._lineage
    del f, rv
    gc.collect()

    def _gone():
        with backend._lineage_lock:
            return digest not in backend._lineage
    _wait(_gone, what="GC-driven lineage drop")


def test_lineage_registry_is_bounded():
    rc.plan("cluster", workers=1, lineage_keep=2)
    backend = rc.active_backend()
    fs = [future(_big, 100.0 + i) for i in range(3)]
    rvs = [_remote_value_of(f) for f in fs]
    with backend._lineage_lock:
        assert len(backend._lineage) <= 2
        assert rvs[0].digest not in backend._lineage   # oldest LRU-evicted
        assert rvs[2].digest in backend._lineage
    assert fs and rvs                                  # keep refs pinned


# --------------------------------------------------------------------------
# Peer fetch promotes the fetcher to a registered replica
# --------------------------------------------------------------------------

def test_peer_fetch_promotes_fetcher_to_replica(tmp_path):
    """A task-path peer fetch leaves a second registered holder behind
    (the ("stored", d, n, "fetch") confirmation) — hot digests gain
    replicas from ordinary traffic."""
    rc.plan("cluster", workers=2)
    backend = rc.active_backend()
    pidfile, release = str(tmp_path / "pid"), str(tmp_path / "go")
    f = future(_big, 41.5)
    digest = _remote_value_of(f).digest
    assert len(backend.locations(digest)) == 1
    # occupy the holder deterministically: this chain is locality-routed
    blocker = f.then(_make_parker(pidfile, release))
    _wait(lambda: os.path.exists(pidfile), what="parker pinned on holder")
    g = f.then(lambda a: float(a.sum()))   # holder busy -> other worker
    assert g.value() == float(_big(41.5).sum())
    _wait(lambda: len(backend.locations(digest)) >= 2,
          what="fetcher promoted to replica")
    assert backend.recovery_stats()["replica_promotions"] >= 1
    open(release, "w").close()
    assert blocker.value() == float(_big(41.5)[0])


# --------------------------------------------------------------------------
# Slow peer: death verdict races the original bytes coming back
# --------------------------------------------------------------------------

@pytest.mark.launcher
def test_slow_holder_races_reconstruction():
    """Freeze the sole holder past the heartbeat timeout: the driver
    declares it dead and rebuilds from lineage while the frozen process
    (whose peer server still has the original bytes) resumes
    mid-recovery. Content addressing makes the race benign — both copies
    are the same digest, so whichever side wins, the value is
    bit-identical."""
    h = HarnessLauncher()
    rc.plan("cluster", hosts=2, launcher=h, **_FAST)
    backend = rc.active_backend()
    f = future(_big, 37.5)
    digest = _remote_value_of(f).digest
    pid = next(iter(_holder_pids(backend, digest)))
    wp = h.by_pid(pid)
    assert wp is not None
    h.delay(wp, 6.0)       # > heartbeat_timeout: declared dead, then back
    _wait(lambda: not backend.locations(digest),
          what="frozen holder declared dead")
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_big(37.5).sum())
    assert np.array_equal(f.value(), _big(37.5))
    assert backend.recovery_stats()["reconstructions"] >= 1
    # the pool keeps serving fresh work after the zombie resumes
    assert future(lambda: 7).value() == 7
