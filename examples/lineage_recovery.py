"""Lineage-based recovery of lost worker-resident results, end to end.

Large cluster results stay on the producing worker (the driver holds a
content-addressed ``RemoteValue``). That is great for locality and wire
bytes — and a liability when the holder dies. This example shows the two
layers of the robustness story:

1. **Reconstruction.** The driver records, per held digest, the producing
   task (with its frozen RNG stream key and content-addressed input refs)
   and its remote parents. When every copy of a digest is gone — the
   holder was SIGKILLed, or store pressure evicted it everywhere — a pull
   or a dependent dispatch transparently re-executes that lineage on a
   surviving worker, recursing into missing parents. The replay is
   **digest-identical**: the rebuilt bytes register under the original
   digest, so dependent futures resolve to the bit-exact value instead of
   failing with WorkerDiedError. Caps (``lineage_max_depth``,
   ``lineage_max_attempts``) turn pathological cases into a clear
   ``LineageExhaustedError``.

2. **Replication.** ``plan("cluster", ..., min_replicas=2)`` pushes a
   second copy of every newly held result to a different worker, off the
   hot path — then a single holder death needs *zero* re-executions: the
   surviving replica serves the chain. Ordinary peer fetches promote the
   fetcher to a registered replica too, so hot digests spread for free.

Run: PYTHONPATH=src python examples/lineage_recovery.py
"""

import os
import signal
import time

import numpy as np

import repro.core as rc
from repro.core import future


def _payload(bias):
    return np.arange(1 << 18, dtype=np.float64) + bias   # 2 MiB


def _kill_one_holder(backend, digest):
    """SIGKILL one worker the driver lists as a holder of ``digest``,
    then wait until the death verdict prunes that worker from the
    location map — an observable driver state, not a sleep."""
    wids = backend.locations(digest)
    with backend._pool_cv:
        wid, pid = next((w.wid, w.meta.get("pid"))
                        for w in backend._all if w.wid in wids)
    os.kill(pid, signal.SIGKILL)
    deadline = time.monotonic() + 30.0
    while wid in backend.locations(digest) \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    return pid


def demo_reconstruction():
    print("== reconstruction: kill the sole holder, chain anyway ==")
    rc.plan("cluster", hosts=2,
            heartbeat_interval=0.1, heartbeat_timeout=3.0,
            relaunch_backoff=0.05, relaunch_backoff_cap=0.2)
    backend = rc.active_backend()
    f = future(_payload, 3.0)             # 2 MiB result, worker-resident
    run = f._backend.collect(f._handle)
    digest = run.value.digest
    pid = _kill_one_holder(backend, digest)
    print(f"killed holder pid {pid}; locations now "
          f"{backend.locations(digest) or '{}'}")

    g = f.then(lambda a: float(a.sum()))  # needs the lost intermediate
    expect = float(_payload(3.0).sum())
    assert g.value() == expect, "chain must resolve to the exact value"
    stats = backend.recovery_stats()
    print(f"chain resolved to {g.value():.1f} (exact); "
          f"recovery_stats={stats}")
    assert stats["reconstructions"] >= 1
    # the rebuilt blob lives under the ORIGINAL digest: bit-identical
    assert np.array_equal(f.value(), _payload(3.0))
    print("pull by the original digest: bit-identical bytes\n")
    rc.shutdown()


def demo_replication():
    print("== min_replicas=2: same death, zero re-executions ==")
    rc.plan("cluster", hosts=2, min_replicas=2,
            heartbeat_interval=0.1, heartbeat_timeout=3.0,
            relaunch_backoff=0.05, relaunch_backoff_cap=0.2)
    backend = rc.active_backend()
    f = future(_payload, 7.0)
    run = f._backend.collect(f._handle)
    digest = run.value.digest
    deadline = time.monotonic() + 30.0
    while len(backend.locations(digest)) < 2 \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    print(f"replicated to workers {sorted(backend.locations(digest))}")

    _kill_one_holder(backend, digest)     # kills ONE of the two holders
    g = f.then(lambda a: float(a.sum()))
    assert g.value() == float(_payload(7.0).sum())
    stats = backend.recovery_stats()
    print(f"chain served by the surviving replica; recovery_stats={stats}")
    assert stats["reconstructions"] == 0, "replica means no re-execution"
    rc.shutdown()


def main():
    demo_reconstruction()
    demo_replication()
    print("OK: lost results rebuilt digest-identical; replicas make "
          "recovery free")


if __name__ == "__main__":
    main()
