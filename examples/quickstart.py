"""Quickstart: the Future API and the streaming frontend built on it.

Mirrors the paper's running examples (the three constructs, plan(),
relaying, parallel RNG, EITHER, fault tolerance), then shows the layer the
paper argues those constructs are sufficient to build: `stream()` pipelines
with bounded in-flight backpressure — map-reduce over sources too large to
materialize.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import itertools
import time
import warnings

import repro.core as rc
from repro.core import (ListEnv, future, future_either, future_map, plan,
                        resolved, stream, value)


def slow_fcn(x):
    time.sleep(0.05)
    return x * x


def main():
    # -- the three constructs (paper §Three atomic constructs) -------------
    plan("sequential")
    x = 1
    f = future(lambda: slow_fcn(x))
    x = 2                       # snapshot semantics: the future saw x == 1
    print("value(f) =", value(f), "(uses x=1, not x=2)")

    # -- end-user picks the backend; the code above does not change --------
    plan("threads", workers=2)
    fs = [future(lambda i=i: slow_fcn(i)) for i in range(3)]
    print("resolved? ", resolved(fs))
    print("values:   ", value(fs))

    # -- parallel for-loop via a list environment (paper: listenv) ---------
    env = ListEnv()
    for i in range(4):
        env[i] = future(lambda i=i: slow_fcn(i))
    print("listenv:  ", env.as_list())

    # -- streaming pipelines (the frontend layer on the three constructs) --
    #
    # stream() never materializes its source and keeps at most
    # max_in_flight futures outstanding (default 2 * workers), dispatching
    # through the backend admission protocol the moment a worker frees —
    # not by blocking inside submit. Chain .filter/.batch/.map stages,
    # then collect ordered, iterate as completed, or fold with .reduce.
    s = stream(range(12), max_in_flight=4)
    print("stream:   ", s.map(slow_fcn, chunk=3).collect(ordered=True))
    print("          peak in-flight:", s.stats["peak_in_flight"],
          "of cap", s.stats["max_in_flight"])

    # -- streaming reduce over a generator too large to materialize --------
    #
    # Ten million squares would need ~GBs as a list; the stream holds
    # O(in-flight) chunks instead — same code shape at any length,
    # including unbounded generators.
    big = (i for i in range(10_000_000))
    total = (stream(big, max_in_flight=4)
             .batch(500_000)               # one future per 500k-element slab
             .map(lambda xs: sum(v * v for v in xs), chunk=1)
             .reduce(lambda a, b: a + b))  # folds as results complete
    print("streamed sum of 10M squares:", total)

    # -- eager map-reduce (future.apply analogue; now sugar over stream) ---
    print("future_map:", future_map(slow_fcn, range(8)))

    # -- exception + condition relay (paper §Exception handling/§Relaying) -
    def noisy():
        print("Hello world")
        warnings.warn("Missing values were omitted")
        print("Bye bye")
        return 55

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        v = value(future(noisy))
    print(f"noisy future -> {v}; relayed warnings: "
          f"{[str(x.message) for x in w]}")

    try:
        value(future(lambda: [0][3]))
    except IndexError as e:
        print("relayed as-is:", type(e).__name__, "-", e)

    # -- backend-invariant parallel RNG (paper §parallel RNG) --------------
    import jax
    rc.set_session_seed(42)

    def draw(x, key):
        return float(jax.random.normal(key, ()))

    a = future_map(draw, [0, 0, 0], seed=True, chunks=1)
    rc.set_session_seed(42)
    b = stream([0, 0, 0], max_in_flight=1).map(draw, seed=True).collect()
    print("rng invariant to frontend/chunking/in-flight:", a == b, a)

    # -- EITHER construct (paper §Other uses) -------------------------------
    winner = future_either(
        lambda: (time.sleep(2.0), "shell sort")[1],
        lambda: (time.sleep(0.01), "radix sort")[1],
    )
    print("future_either winner:", winner)

    # -- cooperative concurrency: await f / async for (asyncio frontend) ----
    #
    # Every future is awaitable: `await f` suspends the coroutine instead
    # of blocking its thread, on any backend. plan("asyncio") goes further
    # and runs `async def` bodies on one shared event loop — thousands of
    # I/O-bound futures in flight with no thread parked per future.
    import asyncio
    plan("asyncio")

    async def fetch(i):
        await asyncio.sleep(0.02 * (3 - i % 3))    # stand-in for real I/O
        return i * 10

    async def cooperative_demo():
        fs = [future(fetch, i) for i in range(6)]
        one = await fs[0]                          # await ≡ value(), non-blocking
        # multiplex completions into the loop: futures arrive as they finish
        done = [await f async for f in rc.as_completed_async(fs)]
        # stream terminals have async twins for use inside a running loop
        squares = await (stream(range(8))
                         .map(lambda v: v * v)
                         .collect_async())
        return one, done, squares

    one, done, squares = asyncio.run(cooperative_demo())
    print("await f:  ", one)
    print("async for:", done, "(completion order)")
    print("stream.collect_async:", squares)

    # -- worker processes + fault tolerance ---------------------------------
    plan("processes", workers=2)
    import os
    print("worker pid:", value(future(lambda: os.getpid())),
          "(parent:", str(os.getpid()) + ")")

    def die():
        os._exit(9)

    try:
        value(future(die))
    except rc.WorkerDiedError as e:
        print("node failure detected:", e)
    print("pool self-healed:", value(future(lambda: "alive")))

    # -- streaming + retries ride the same fault model ----------------------
    # (an unbounded source with as_completed(): take five results and move
    # on; breaking out cancels the in-flight tail)
    first_five = []
    for r in stream(itertools.count()).map(lambda v: v * 10, chunk=2) \
            .as_completed():
        first_five.append(r)
        if len(first_five) >= 5:
            break
    print("first five from an unbounded stream:", sorted(first_five))
    rc.shutdown()


if __name__ == "__main__":
    main()
