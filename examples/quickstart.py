"""Quickstart: the Future API, mirroring the paper's running examples.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import time
import warnings

import repro.core as rc
from repro.core import (ListEnv, future, future_either, future_map, plan,
                        resolved, value)


def slow_fcn(x):
    time.sleep(0.05)
    return x * x


def main():
    # -- the three constructs (paper §Three atomic constructs) -------------
    plan("sequential")
    x = 1
    f = future(lambda: slow_fcn(x))
    x = 2                       # snapshot semantics: the future saw x == 1
    print("value(f) =", value(f), "(uses x=1, not x=2)")

    # -- end-user picks the backend; the code above does not change --------
    plan("threads", workers=2)
    fs = [future(lambda i=i: slow_fcn(i)) for i in range(3)]
    print("resolved? ", resolved(fs))
    print("values:   ", value(fs))

    # -- parallel for-loop via a list environment (paper: listenv) ---------
    env = ListEnv()
    for i in range(4):
        env[i] = future(lambda i=i: slow_fcn(i))
    print("listenv:  ", env.as_list())

    # -- map-reduce with load-balanced chunking (future.apply analogue) ----
    print("future_map:", future_map(slow_fcn, range(8)))

    # -- exception + condition relay (paper §Exception handling/§Relaying) -
    def noisy():
        print("Hello world")
        warnings.warn("Missing values were omitted")
        print("Bye bye")
        return 55

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        v = value(future(noisy))
    print(f"noisy future -> {v}; relayed warnings: "
          f"{[str(x.message) for x in w]}")

    try:
        value(future(lambda: [0][3]))
    except IndexError as e:
        print("relayed as-is:", type(e).__name__, "-", e)

    # -- backend-invariant parallel RNG (paper §parallel RNG) --------------
    import jax
    rc.set_session_seed(42)

    def draw(x, key):
        return float(jax.random.normal(key, ()))

    a = future_map(draw, [0, 0, 0], seed=True, chunks=1)
    rc.set_session_seed(42)
    b = future_map(draw, [0, 0, 0], seed=True, chunks=3)
    print("rng invariant to chunking:", a == b, a)

    # -- EITHER construct (paper §Other uses) -------------------------------
    winner = future_either(
        lambda: (time.sleep(2.0), "shell sort")[1],
        lambda: (time.sleep(0.01), "radix sort")[1],
    )
    print("future_either winner:", winner)

    # -- worker processes + fault tolerance ---------------------------------
    plan("processes", workers=2)
    import os
    print("worker pid:", value(future(lambda: os.getpid())),
          "(parent:", str(os.getpid()) + ")")

    def die():
        os._exit(9)

    try:
        value(future(die))
    except rc.WorkerDiedError as e:
        print("node failure detected:", e)
    print("pool self-healed:", value(future(lambda: "alive")))
    rc.shutdown()


if __name__ == "__main__":
    main()
