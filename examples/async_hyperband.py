"""Asynchronous successive halving (ASHA) on the shared-state subsystem.

The workload the paper's *synchronous* future constructs cannot express
alone: hyperparameter search where workers publish partial results **as
they finish each rung** and the driver prunes losers **mid-flight** —
nobody waits for a generation barrier. The shared-state service
(``repro.core.state``) is the missing channel:

* each trial is one ordinary ``future()`` on a *launched* cluster
  (``spec("cluster", hosts=2)`` — the launcher subsystem bootstraps the
  fleet; zero hand-started processes);
* the trial body publishes its loss at rung ``r`` with
  ``state.put(f"rung/{r}/{cid}", loss)`` and polls its own kill switch
  ``state.get(f"stop/{cid}")`` at every rung boundary;
* the driver never blocks on any single trial: it watches the rung
  boards with ``state.keys(prefix)``, ranks whatever has been reported
  *so far*, and flips the stop keys of trials outside the top ``1/eta``
  — the asynchronous-halving rule.

Every arrow in that picture is a versioned KV op on the driver-hosted
:class:`~repro.core.state.StateService`; the trials see it through the
same ``state.*`` calls they would use in-process (the ambient task
context routes them over the cluster's control sockets).

Walkthrough of one run (eta=2, 4 rungs, 8 trials): all 8 report at rung
0; the driver keeps the best 4 and flips ``stop/<cid>`` for the rest,
*while those trials are still training* — they notice at their next rung
boundary and return early with status ``"pruned"``. The survivors repeat
at rung 1 (keep 2) and rung 2 (keep 1), so roughly ``N * (1 + 1/2 + 1/4
+ ...)`` epochs of work are spent instead of ``N * RUNGS`` — and because
pruning is asynchronous, a straggler cannot hold back a winner.

Run: PYTHONPATH=src python examples/async_hyperband.py
"""

import math
import time

import repro.core as rc
from repro.core import future, gather, plan, spec, state, value

ETA = 2          # keep the top 1/ETA at every rung
RUNGS = 4
N_TRIALS = 8


def make_trial_body(rungs: int):
    """Build the trial body as a *local* function so it ships to the
    launched workers by value (a module global in an example script would
    pickle by reference to a module the workers cannot import)."""
    def train_trial(cid: int, lr: float, _rungs=rungs):
        """One trial: simulated training reporting per-rung validation
        loss to the shared-state board, honouring its stop key. The loss
        model rewards lr near 0.1 with diminishing returns per rung —
        deterministic, so the demo's winner is reproducible."""
        import time as _time
        from repro.core import state
        loss = None
        for r in range(_rungs):
            if state.get(f"stop/{cid}", False):
                return {"cid": cid, "status": "pruned",
                        "rung": r, "loss": loss}
            # later rungs cost more (like real epochs over growing budgets)
            # and per-trial jitter keeps the reports asynchronous
            _time.sleep(0.04 * (r + 1) * (1 + (cid * 7) % 3) / 2)
            loss = (lr - 0.1) ** 2 + 0.5 / (r + 1)
            state.put(f"rung/{r}/{cid}", loss)
        return {"cid": cid, "status": "done", "rung": _rungs, "loss": loss}
    return train_trial


def asha_prune_pass():
    """One driver-side pruning sweep: for every rung, rank the trials
    that have reported *so far* and flip the stop key of any trial
    outside the top ceil(n / ETA). Asynchronous: acts on partial boards,
    never waits for a full generation."""
    stopped = []
    for r in range(RUNGS - 1):                   # last rung never prunes
        board = []
        for key in state.keys(f"rung/{r}/"):
            cid = int(key.rsplit("/", 1)[1])
            board.append((state.get(key), cid))
        if len(board) < ETA:
            continue                             # too early to judge
        board.sort()
        keep = math.ceil(len(board) / ETA)
        for _loss, cid in board[keep:]:
            if not state.get(f"stop/{cid}", False):
                state.put(f"stop/{cid}", True)
                stopped.append((r, cid))
    return stopped


def main():
    plan(spec("cluster", hosts=2))               # launcher boots the fleet
    lrs = [0.1 * (1.6 ** (i - 3)) for i in range(N_TRIALS)]
    body = make_trial_body(RUNGS)
    trials = [future(lambda c=i, lr=lr, b=body: b(c, lr))
              for i, lr in enumerate(lrs)]

    # the driver's ASHA loop: poll the rung boards while trials fly
    done = gather(trials)
    while not rc.resolved(done):
        for rung, cid in asha_prune_pass():
            print(f"  rung {rung}: pruned trial {cid} "
                  f"(lr={lrs[cid]:.4f}) mid-flight")
        time.sleep(0.02)

    results = value(done)
    survivors = [t for t in results if t["status"] == "done"]
    best = min(survivors, key=lambda t: t["loss"])
    print("\ntrial outcomes:")
    for t in sorted(results, key=lambda t: t["cid"]):
        print(f"  trial {t['cid']}: lr={lrs[t['cid']]:.4f} "
              f"{t['status']:6s} at rung {t['rung']} loss={t['loss']}")
    epochs = sum(t["rung"] for t in results)
    print(f"\nbest: trial {best['cid']} (lr={lrs[best['cid']]:.4f}, "
          f"loss={best['loss']:.4f})")
    print(f"epochs spent: {epochs} of {N_TRIALS * RUNGS} synchronous")
    assert len(survivors) < N_TRIALS, "pruning never fired"
    rc.shutdown()


if __name__ == "__main__":
    main()
