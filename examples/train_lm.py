"""End-to-end training driver: xLSTM-125M (the ~100M-param assigned arch).

Futures at work in the loop: prefetched data batches, async checkpoints,
progress relay. Defaults are CPU-sized (reduced model, 50 steps); pass
``--full --steps 300`` for the real 125M config / a few hundred steps
(hours on this 1-core host — sized for a real machine).

Run: PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""

import argparse

import repro.core as rc
from repro.configs import get_arch
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="real 125M config instead of the reduced one")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    rc.plan("threads", workers=2)      # data prefetch + ckpt writer overlap
    cfg = get_arch("xlstm-125m", smoke=not args.full)
    batch = args.batch or (8 if args.full else 8)
    seq = args.seq or (512 if args.full else 64)

    tcfg = TrainerConfig(steps=args.steps, batch=batch, seq=seq,
                         log_every=max(args.steps // 10, 1),
                         ckpt_every=max(args.steps // 4, 1),
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, tcfg, AdamWConfig(
        lr=3e-3 if not args.full else 6e-4,
        warmup_steps=max(args.steps // 20, 1), total_steps=args.steps))
    state, history = trainer.run()
    first, last = history[0], history[-1]
    print(f"\nloss: {first['loss']:.4f} (step {first['step']}) -> "
          f"{last['loss']:.4f} (step {last['step']})")
    print(f"tokens/s: {last['step'] * batch * seq / last['wall_s']:.0f}")
    print(f"checkpoints in {args.ckpt_dir}: latest step "
          f"{trainer.ckpt.latest_step()}")
    rc.shutdown()


if __name__ == "__main__":
    main()
