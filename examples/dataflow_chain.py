"""Worker-to-worker dataflow: locality-scheduled continuation chains.

The paper's future semantics say nothing about *where* a chained
continuation runs — only that ``f.then(g)`` sees ``f``'s value. The naive
implementation (and this repo's, before the dataflow PR) gathers every
intermediate to the driver: for ``f.then(g).then(h)`` over 8 MiB arrays
that is three 8 MiB result frames through one socket, serialized twice
each, even though no human ever looks at the intermediates.

Since the dataflow PR, cluster task results above ``RESULT_REF_THRESHOLD``
stay resident on the producing worker as content-addressed blobs (the same
blake2b ``BlobStore`` the globals cache uses). The driver's result frame
carries a ~100 B ``RemoteValue`` handle plus the digest's holder location;
each ``then``/``map`` hop is then scheduled *onto the holder* as a ~500 B
control frame, and only the final ``value()`` pull moves real bytes. When
the scheduler places a hop on a worker that does not hold the parent blob
(holder busy, or died), the worker fetches it peer-to-peer from another
holder — falling back to the driver's copy only when no peer has it.

This demo runs the same 3-link chain both ways and prints the driver's
wire traffic. Expect ~1000x fewer driver bytes worker-resident::

    $ PYTHONPATH=src python examples/dataflow_chain.py
    driver-gathered : 8,430,104 B through driver/chain, ...
    worker-resident :     6,480 B through driver/chain, ...
    reduction       : ~1301x fewer bytes through the driver

Nothing about the *semantics* changed: values, exception relay, and RNG
streams are bit-identical either way (the conformance matrix pins this on
all six backends), and ``remote_results=False`` restores the old
gather-everything behaviour wholesale.
"""

import time

import numpy as np

import repro.core as rc
from repro.core.backends import transport

N = 1 << 20                                  # 8 MiB of float64 per link


def run_chain() -> float:
    f = rc.future(lambda: np.arange(N, dtype=np.float64))
    return (f.then(lambda a: a + 1.0)        # hop 1: runs on f's holder
             .then(lambda a: a * 2.0)        # hop 2: same holder, 0 copies
             .then(lambda a: float(a[-1]))   # hop 3: scalar comes home
             .value())


def measure(remote_results: bool, reps: int = 3) -> tuple[float, float]:
    rc.plan("cluster", workers=2, remote_results=remote_results)
    rc.value(rc.future(lambda: 1))           # warm connections
    run_chain()                              # warm the shipped-code cache
    transport.reset_wire_stats()
    t0 = time.perf_counter()
    for _ in range(reps):
        assert run_chain() == float(N) * 2.0
    dt = (time.perf_counter() - t0) / reps
    stats = transport.wire_stats()
    rc.shutdown()
    return (stats["bytes_sent"] + stats["bytes_recv"]) / reps, dt


def main() -> None:
    legacy_b, legacy_s = measure(remote_results=False)
    print(f"driver-gathered : {legacy_b:>12,.0f} B through driver/chain, "
          f"{legacy_s * 1e3:.1f}ms/chain")
    flow_b, flow_s = measure(remote_results=True)
    print(f"worker-resident : {flow_b:>12,.0f} B through driver/chain, "
          f"{flow_s * 1e3:.1f}ms/chain")
    print(f"reduction       : ~{legacy_b / max(flow_b, 1):.0f}x fewer "
          f"bytes through the driver")

    # where did the value actually live? value() is the explicit pull —
    # until then the 8 MiB intermediate exists only in worker blob stores
    rc.plan("cluster", workers=2)
    f = rc.future(lambda: np.arange(N, dtype=np.float64))
    g = f.then(lambda a: a.sum())
    print(f"g.value() pulls : {g.value():.0f} (computed where a lived)")
    rc.shutdown()
    rc.plan("sequential")


if __name__ == "__main__":
    main()
