"""Parameter-server training on the shared-state subsystem.

The classic asynchronous-SGD topology, expressed with nothing but
``future()`` + ``repro.core.state``: the driver hosts the model as one
versioned entry, and every worker loops

    snapshot = state.get("ps")          # pull current params + opt state
    grads    = grad(loss)(snapshot)     # local compute, stale-ok
    state.update("ps", commit)          # atomic read-modify-write

where ``commit`` applies *this worker's* gradient to whatever the entry
holds **now** via :func:`repro.optim.adamw.apply_updates`. ``update`` is
the linearizable RMW primitive — on the cluster backend it is a CAS retry
loop over the driver's versioned entry, so two workers committing
concurrently never lose a step: the loser's ``commit`` re-runs against
the winner's result (asynchronous AdamW with atomic applies, gradients
computed on slightly stale params — the standard PS consistency model).

The entry's version number *is* the global step counter: after W workers
each commit S updates, ``state.version("ps") == W * S`` exactly — the
no-lost-updates property the conformance suite pins on every backend.

Run: PYTHONPATH=src python examples/param_server.py
"""

import numpy as np

import repro.core as rc
from repro.core import future, gather, plan, state, value
from repro.optim.adamw import AdamWConfig, init_state

DIM = 16
WORKERS = 4
STEPS = 12               # optimizer commits per worker
CFG = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=WORKERS * STEPS,
                  weight_decay=0.0)


def make_problem(seed: int = 0):
    """Synthetic least squares: recover w* from noisy linear measurements."""
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=(DIM,))
    xs = rng.normal(size=(256, DIM))
    ys = xs @ w_star + 0.01 * rng.normal(size=(256,))
    return w_star, xs, ys


def loss_of(params, xs, ys) -> float:
    import jax.numpy as jnp
    pred = xs @ params["w"]
    return float(jnp.mean((pred - ys) ** 2))


def make_worker_body(xs, ys, cfg, steps):
    """Local function so it ships to cluster workers by value."""
    def body(wid: int, _xs=xs, _ys=ys, _cfg=cfg, _steps=steps):
        import jax
        import jax.numpy as jnp
        from repro.core import state
        from repro.optim.adamw import apply_updates

        def loss_fn(params, batch_x, batch_y):
            pred = batch_x @ params["w"]
            return jnp.mean((pred - batch_y) ** 2)

        grad_fn = jax.grad(loss_fn)
        rng = np.random.default_rng(1000 + wid)
        for _ in range(_steps):
            # pull a snapshot (possibly stale by a few commits: PS model)
            snap = state.get("ps")
            idx = rng.integers(0, _xs.shape[0], size=32)
            grads = grad_fn(snap["params"],
                            jnp.asarray(_xs[idx]), jnp.asarray(_ys[idx]))

            def commit(cur, g=grads):
                # atomic apply against the *current* entry — under
                # contention this fn re-runs on the winner's result, so
                # every gradient lands exactly once
                p2, s2, _metrics = apply_updates(
                    _cfg, cur["params"], g, cur["opt"])
                return {"params": p2, "opt": s2}

            state.update("ps", commit)
        return state.stats()["cas_retries"]
    return body


def main():
    plan("cluster", workers=WORKERS)
    w_star, xs, ys = make_problem()

    # the driver seeds the model entry: params + optimizer state together,
    # one key, so a commit is atomic over both
    import jax.numpy as jnp
    params = {"w": jnp.zeros((DIM,))}
    state.put("ps", {"params": params, "opt": init_state(params)})
    loss0 = loss_of(params, xs, ys)

    body = make_worker_body(xs, ys, CFG, STEPS)
    retries = value(gather([future(lambda i=i, b=body: b(i))
                            for i in range(WORKERS)]))

    final = state.get("ps")
    loss1 = loss_of(final["params"], xs, ys)
    steps = state.version("ps") - 1          # v1 was the seed put
    print(f"workers={WORKERS} steps/worker={STEPS} "
          f"commits={steps} cas_retries={sum(retries)}")
    print(f"loss: {loss0:.4f} -> {loss1:.4f}   "
          f"|w - w*|: {float(np.linalg.norm(np.asarray(final['params']['w']) - w_star)):.4f}")
    assert steps == WORKERS * STEPS, "lost or duplicated a commit"
    assert loss1 < loss0 * 0.5, "training did not make progress"
    rc.shutdown()


if __name__ == "__main__":
    main()
