"""Multi-tenant serving: two client *processes*, one warm cluster.

The paper's split — developers declare parallelism, end-users pick the
backend — stops at process boundaries: every ``plan("cluster")`` owns its
own worker fleet. The serving tier removes that limit. One long-lived
server process wraps a warm cluster behind TLS + token auth; any number
of client processes ``plan("serving", addr=..., token=...)`` and get the
full Future/stream/state API, each mapped to a *tenant* with a fair-share
weight.

This script plays both roles:

* no argv — the **server**: starts ``serve()`` with a self-signed cert,
  two tenant credentials (alice weight 3, bob weight 1), spawns itself
  twice as client subprocesses, then prints the per-tenant attribution
  the fair-share scheduler recorded.
* ``--client ADDR TENANT TOKEN CA`` — a **client**: plans onto the
  serving backend and runs a ``stream()`` workload plus a shared-state
  fold, exactly as it would against a private cluster. The tenant's state
  namespace is private: both clients use the same keys without collision.

Run: PYTHONPATH=src python examples/multi_tenant_serving.py
"""

import subprocess
import sys
import time

ITEMS = 24


def run_client(addr: str, tenant: str, token: str, ca: str) -> None:
    import repro.core as rc
    from repro.core import plan, state, stream

    plan("serving", addr=addr, token=token, tls_ca=ca)
    t0 = time.perf_counter()

    # a stream() workload: admission flows through the session's
    # free_slots RPC, dispatch through the server's fair-share scheduler
    total = (stream(range(ITEMS))
             .map(lambda i: i * i)
             .reduce(lambda a, b: a + b))
    assert total == sum(i * i for i in range(ITEMS))

    # shared state, namespaced per tenant: both clients fold into
    # "progress" yet never see each other's counter
    for _ in range(5):
        state.add("progress", 1)
    done, _ver = state.add("progress", 0)

    stats = rc.planning.active_backend().session_stats()
    wall = time.perf_counter() - t0
    print(f"[{tenant}] sum(i^2, i<{ITEMS}) = {total}, "
          f"progress = {done}, "
          f"completed = {stats['tenant_stats']['completed']}, "
          f"bytes_sent = {stats['tenant_stats']['bytes_sent']}, "
          f"{wall:.2f}s", flush=True)
    plan("sequential")
    rc.shutdown()


def run_server() -> None:
    from repro.core.serving import serve

    with serve({"workers": 2},
               tokens={"alice": "alice-secret", "bob": "bob-secret"},
               tenants={"alice": {"weight": 3.0},
                        "bob": {"weight": 1.0}},
               tls=True) as srv:
        host, port = srv.address
        addr = f"{host}:{port}"
        print(f"server: cluster of {srv.inner.workers} workers behind "
              f"TLS+token on {addr}", flush=True)
        clients = [
            subprocess.Popen([sys.executable, __file__, "--client", addr,
                              name, f"{name}-secret", srv.tls.certfile])
            for name in ("alice", "bob")
        ]
        for p in clients:
            rc = p.wait(timeout=120)
            assert rc == 0, f"client exited {rc}"
        print("server: per-tenant attribution",
              srv.inner.tenant_stats(), flush=True)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--client":
        run_client(*sys.argv[2:6])
    else:
        run_server()
