"""Repeated dispatch over a large global: content-addressed shipping demo.

The paper's automatic-globals semantics snapshot and ship every global with
every future. For the dominant scaled-up workload — ``future_map`` /
training-step dispatch over the same multi-MB arrays — that re-sends the
world on every dispatch. Since the payload-pipeline PR, shipping is
content-addressed:

* the first future referencing an 8 MiB float32 array pays one ``put``
  frame — ~2 MiB here because this demo opts into the int8+EF transport
  codec (~4x vs raw pickle, where zlib-1 managed ~1.10x; the codec is
  lossy, so by default arrays ship losslessly and the first send is
  ~8 MiB);
* every later future ships a few-hundred-byte task blob holding a 16-byte
  digest; the worker resolves it from a bounded LRU blob store (with a
  decoded-object cache, so it does not even re-unpickle);
* re-``plan()``-ing to a previously used spec re-attaches to the live
  workers, blob caches intact (warm pool) — ``plan("threads")`` round-trips
  no longer cold-start jax imports.

Run::

    PYTHONPATH=src python examples/payload_cache.py

Typical output (one local TCP cluster worker)::

    first dispatch : 2099000 B on the wire, 3.99x smaller than raw pickle
    cache-hit      : 508 B on the wire (4131x less), 1.1ms/future
    warm re-plan   : same worker pid after threads round-trip, cache warm
"""

import time

import numpy as np

import repro.core as rc
from repro.core.backends import transport


def main() -> None:
    big = np.sin(np.arange(2 * 1024 * 1024, dtype=np.float32))   # 8 MiB
    import pickle
    raw = len(pickle.dumps(big, pickle.HIGHEST_PROTOCOL))

    # quantization-tolerant workload (weights/gradients): opt into the
    # lossy int8+EF codec for the 4x first-send reduction
    transport.set_array_codec("int8")

    rc.plan("cluster", workers=1)
    rc.value(rc.future(lambda: 1))                  # warm the connection

    transport.reset_wire_stats()
    t0 = time.perf_counter()
    rc.value(rc.future(lambda: float(big[3])))
    first_s = time.perf_counter() - t0
    first_b = transport.wire_stats()["bytes_sent"]
    print(f"first dispatch : {first_b} B on the wire "
          f"({raw / first_b:.2f}x smaller than raw pickle), "
          f"{first_s * 1e3:.1f}ms")

    n = 20
    base = transport.wire_stats()["bytes_sent"]
    t0 = time.perf_counter()
    for _ in range(n):
        rc.value(rc.future(lambda: float(big[3])))
    hit_s = (time.perf_counter() - t0) / n
    hit_b = (transport.wire_stats()["bytes_sent"] - base) / n
    print(f"cache-hit      : {hit_b:.0f} B on the wire "
          f"({first_b / hit_b:.0f}x less), {hit_s * 1e3:.1f}ms/future")

    pid_before = rc.active_backend().worker_pids()
    rc.plan("threads", workers=2)                   # interlude on threads
    rc.value(rc.future(lambda: "hi"))
    rc.plan("cluster", workers=1)                   # warm pool re-attach
    pid_after = rc.active_backend().worker_pids()
    transport.reset_wire_stats()
    rc.value(rc.future(lambda: float(big[4])))
    replan_b = transport.wire_stats()["bytes_sent"]
    print(f"warm re-plan   : worker pids {pid_before} -> {pid_after} "
          f"(reused={pid_before == pid_after}), "
          f"{replan_b} B on the wire (cache still warm)")

    rc.shutdown()
    rc.plan("sequential")


if __name__ == "__main__":
    main()
