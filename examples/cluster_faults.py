"""Multi-pod training under failures: the paper's fault-tolerance story.

Runs the real multi-pod driver — each pod a worker process connected to the
TCP socket cluster backend — while injecting: (1) a hard node failure
mid-round, (2) a straggler pod raced by a speculative duplicate, (3) an
elastic resize between rounds. The run must finish with a decreasing loss
despite all three.

Worker bootstrap is the launcher subsystem's job — nothing here (or in any
multi-host run) is launched by hand. ``plan("cluster", hosts=N)`` spawns N
local workers via the default ``LocalLauncher``; the *same line* bootstraps
real machines by swapping the launcher::

    from repro.core import SSHLauncher, CommandLauncher

    # ssh bootstrap (the paper's makeClusterPSOCK default for named hosts;
    # reverse_tunnel=True lets NAT'd workers dial back through the tunnel —
    # then the loopback default bind is fine, the tunnel delivers to it):
    rc.plan("cluster", hosts=("nodeA", "nodeB"),
            launcher=SSHLauncher(python="python3",
                                 pythonpath="/opt/repro/src",
                                 reverse_tunnel=True))

    # scheduler bootstrap as a config string (SLURM shown; k8s analogous).
    # Remote workers must be able to *reach* the driver: bind a non-
    # loopback address (and advertise= the name they should dial, when the
    # bind is 0.0.0.0 and the default hostname is not resolvable there):
    rc.plan("cluster", hosts=4, bind="0.0.0.0", launcher=CommandLauncher(
        "srun --ntasks=1 {python} -m repro.core.backends.cluster_worker "
        "{driver} --tag {tag}"))

    # hand-launched / pre-existing workers (the old workflow):
    rc.plan("cluster", hosts=2, launcher="external")
    # ... then on each machine:
    #     python -m repro.core.backends.cluster_worker DRIVER_HOST:PORT

Either way the driver owns the fault story: a dead worker's future fails
with WorkerDiedError and a replacement is relaunched on the same host with
capped exponential backoff (see backends/cluster.py).

Run: PYTHONPATH=src python examples/cluster_faults.py
"""

import tempfile
import time

import repro.core as rc
from repro.launch.train import MultiPodDriver, PodRunConfig


def demo_launcher_bootstrap():
    """The zero-hand-launched-processes loop, end to end: plan -> launched
    workers -> futures -> shutdown reaps everything."""
    rc.plan("cluster", hosts=2)           # LocalLauncher bootstraps 2 workers
    backend = rc.active_backend()
    print(f"launched workers (pids {backend.worker_pids()}) "
          f"on {backend.address}")
    assert rc.future_map(lambda x: x * x, [1, 2, 3, 4]) == [1, 4, 9, 16]
    rc.shutdown()
    print("launcher bootstrap OK: zero hand-launched processes")


def main():
    demo_launcher_bootstrap()
    tmp = tempfile.mkdtemp(prefix="repro-cluster-")
    cfg = PodRunConfig(
        arch="xlstm-125m", pods=2, rounds=4, local_steps=3,
        batch=2, seq=32, smoke=True,
        ckpt_dir=f"{tmp}/ckpt",
        fail_marker=f"{tmp}/pod0-die-once",     # pod 0 dies on first touch
        straggle_pod=1, straggle_s=20.0,        # pod 1 is slow in round 0
        straggler_timeout_s=3.0,                # ... and gets raced
    )
    print(f"2 pods, 4 rounds; node-failure + straggler injected; {tmp}")
    driver = MultiPodDriver(cfg)

    t0 = time.time()
    rec0 = driver.run_round(0)
    print(f"round 0 survived failure+straggler: loss={rec0['loss']:.4f} "
          f"({time.time() - t0:.1f}s, straggler was 20s)")
    driver.cfg.straggle_pod = None              # back to healthy pods

    rec1 = driver.run_round(1)
    print(f"round 1: loss={rec1['loss']:.4f}")

    print("elastic resize: 2 -> 3 pods")
    driver.resize(3)
    for rnd in (2, 3):
        rec = driver.run_round(rnd)
        print(f"round {rnd} (3 pods): loss={rec['loss']:.4f}")
        if driver.ckpt:
            driver.ckpt.save(rnd + 1, {str(i): p for i, p in
                                       enumerate(driver.params)})
    if driver.ckpt:
        driver.ckpt.wait()
        print("checkpoint at step", driver.ckpt.latest_step())

    losses = [h["loss"] for h in driver.history]
    print(f"losses: {['%.3f' % l for l in losses]}")
    assert losses[-1] < losses[0], "training failed to progress"
    print("OK: converged through failure, straggler, and resize")
    rc.shutdown()


if __name__ == "__main__":
    main()
