"""Batched serving example: request futures + one decode loop.

Clients submit prompts as *futures* on a thread backend; the serving loop
batches whatever requests are pending (continuous-batching-lite), runs
jitted decode steps against per-slot KV caches, and resolves each client's
future when its sequence finishes. `resolved()` gives clients non-blocking
polling — the Future API as a serving front door.

Run: PYTHONPATH=src python examples/serve.py
"""

import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as rc
from repro.configs import get_arch
from repro.models import Model
from repro.train import make_serve_step


class Server:
    """Greedy decode server with slot-based batching."""

    def __init__(self, arch="xlstm-125m", slots=4, max_new=16):
        self.cfg = get_arch(arch, smoke=True)
        self.model = Model(self.cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.slots = slots
        self.max_new = max_new
        self.step = jax.jit(make_serve_step(self.model))
        self.requests: queue.Queue = queue.Queue()
        self._stop = False

    def submit(self, prompt_tokens: list[int]) -> "rc.Future":
        """Client-facing: returns a future over the generated tokens.

        NB: the reply channel is a Queue, NOT a mutable dict — futures
        snapshot captured mutable containers at creation (the paper's
        globals semantics), so later mutation of a captured dict would be
        invisible. Queues are synchronization objects and pass by
        reference.
        """
        reply: queue.Queue = queue.Queue(1)
        self.requests.put((prompt_tokens, reply))

        def wait():
            return reply.get()

        return rc.future(wait)

    def serve_loop(self):
        """One batch at a time; pads free slots with finished sequences."""
        while not self._stop:
            batch = []
            try:
                batch.append(self.requests.get(timeout=0.2))
            except queue.Empty:
                continue
            while len(batch) < self.slots:
                try:
                    batch.append(self.requests.get_nowait())
                except queue.Empty:
                    break
            self._decode_batch(batch)

    def _decode_batch(self, batch):
        b = len(batch)
        cache = self.model.init_cache(b, max_seq=64, dtype=jnp.float32)
        # prefill via single-token steps (prompts are short here)
        maxlen = max(len(p) for p, _ in batch)
        outs = [[] for _ in range(b)]
        tok = jnp.zeros((b, 1), jnp.int32)
        for t in range(maxlen + self.max_new):
            col = []
            for i, (prompt, _) in enumerate(batch):
                col.append(prompt[t] if t < len(prompt)
                           else int(np.asarray(tok[i, 0])))
            tok = jnp.asarray(col, jnp.int32)[:, None]
            tok, cache = self.step(self.params, cache, tok)
            for i, (prompt, _) in enumerate(batch):
                if t >= len(prompt) - 1:
                    outs[i].append(int(np.asarray(tok[i, 0])))
        for i, (_, reply) in enumerate(batch):
            reply.put(outs[i][:self.max_new])


def main():
    rc.plan("threads", workers=4)
    server = Server()
    loop = threading.Thread(target=server.serve_loop, daemon=True)
    loop.start()

    rng = np.random.default_rng(0)
    t0 = time.time()
    futures = []
    for i in range(6):
        prompt = rng.integers(0, server.cfg.vocab_size, size=4).tolist()
        futures.append((i, prompt, server.submit(prompt)))
        print(f"request {i}: submitted prompt={prompt}")

    pending = dict((i, f) for i, _, f in futures)
    while pending:
        for i, f in list(pending.items()):
            if rc.resolved(f):
                toks = rc.value(f)
                print(f"request {i}: done -> {toks[:8]}... "
                      f"({time.time() - t0:.2f}s)")
                del pending[i]
        time.sleep(0.01)
    server._stop = True
    rc.shutdown()
    print("all requests served")


if __name__ == "__main__":
    main()
